"""Pallas TPU kernel: scatter-combine for the sparse-exchange receive side.

``sparse_exchange.scatter_partials`` folds the received compact partials
(idx, val) into the owner's result vector r[n_local] with the semiring's
combineAll.  The XLA lowering is a segment op — serial scatter traffic on
TPU.  This kernel recasts it as tiled one-hot reduction work:

    onehot[n, t] = (idx[t] == n)            over a (TN, TI) tile
    r[n]        = combineAll_t where(onehot[n, t], val[t], identity)

For plus_times the inner reduce IS a matmul (onehot @ val) and runs on the
MXU; the tropical semirings reduce on the VPU.  The output tile is revisited
along the idx-tile grid axis and accumulated in place — the same pattern as
the dense / ELL kernels.

Pad entries use idx = -1 (or any index outside the covered range): they
match no one-hot row and contribute the identity.  Compare-and-reduce work
is O(T * n_out / tile) — worth it when the serial scatter dominates (large
fan-in partials on real hardware); interpret mode is for parity tests only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.block_gimv.block_gimv import SEMIRINGS, _combine_all, _identity


def _scatter_combine_kernel(idx_ref, val_ref, o_ref, *, semiring: str, tile_n: int):
    t = pl.program_id(1)
    base = pl.program_id(0) * tile_n
    idx = idx_ref[...]                       # (1, TI) int32; <0 or out-of-tile = no-op
    targets = base + jax.lax.broadcasted_iota(jnp.int32, (tile_n, 1), 0)
    onehot = idx == targets                  # (TN, TI)
    ident = _identity(semiring, o_ref.dtype)
    if semiring == "plus_times":
        part = jax.lax.dot_general(
            onehot.astype(o_ref.dtype), val_ref[...].astype(o_ref.dtype),
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=o_ref.dtype,
        )                                    # (TN, 1) — MXU
    else:
        x = jnp.where(onehot, val_ref[...].astype(o_ref.dtype), ident)
        if semiring in ("min_plus", "min_src"):
            part = jnp.min(x, axis=1, keepdims=True)
        else:
            part = jnp.max(x, axis=1, keepdims=True)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = part

    @pl.when(t != 0)
    def _acc():
        o_ref[...] = _combine_all(semiring, o_ref[...], part)


def scatter_combine_pallas(
    idx: jnp.ndarray,
    val: jnp.ndarray,
    n_out: int,
    *,
    semiring: str,
    out_dtype=None,
    tile_n: int = 128,
    tile_t: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """r[n] = combineAll_{t : idx[t] == n} val[t]; empty n -> identity.

    idx/val: [T]; T % tile_t == 0 and n_out % tile_n == 0 (ops.py pads).
    """
    assert semiring in SEMIRINGS
    (T,) = idx.shape
    assert T % tile_t == 0 and n_out % tile_n == 0, (T, n_out, tile_t, tile_n)
    out_dtype = out_dtype or val.dtype

    grid = (n_out // tile_n, T // tile_t)
    out = pl.pallas_call(
        functools.partial(_scatter_combine_kernel, semiring=semiring, tile_n=tile_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_t), lambda i, t: (0, t)),
            pl.BlockSpec((1, tile_t), lambda i, t: (0, t)),
        ],
        out_specs=pl.BlockSpec((tile_n, 1), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, 1), out_dtype),
        interpret=interpret,
    )(idx[None, :], val[None, :])
    return out[:, 0]


def _decode_packed_ids(w_ref, t: int, *, width: int, tile_t: int,
                       set_slots: int, n_local: int) -> jnp.ndarray:
    """Decode this tile's bit-packed ids into flat scatter targets (1, TI).

    ``w_ref`` holds tile_t * width / 32 uint32 words, each packing 32/width
    ids LSB-first (codec.pack_uniform).  The decode is pure shift/mask vector
    work — no gather: per-set word alignment (set_slots % ids-per-word == 0)
    makes word index == slot // ids_per_word globally, so a contiguous slot
    tile maps to a contiguous word tile.  Decoded ids are clamped to the
    sentinel ``n_local`` (the per-set drop slot), which also neutralizes any
    padding garbage, then offset into the owning set's segment.
    """
    k = 32 // width
    words = w_ref[...]                                    # (1, TI // k) uint32
    sh = (jax.lax.broadcasted_iota(jnp.uint32, (1, tile_t // k, k), 2)
          * jnp.uint32(width))
    mask = jnp.uint32((1 << width) - 1)
    ids = ((words[..., None] >> sh) & mask).reshape(1, tile_t).astype(jnp.int32)
    g = t * tile_t + jax.lax.broadcasted_iota(jnp.int32, (1, tile_t), 1)
    seg = g // set_slots
    return jnp.minimum(ids, n_local) + seg * (n_local + 1)


def _packed_scatter_kernel(w_ref, val_ref, o_ref, *, semiring: str, tile_n: int,
                           tile_t: int, width: int, set_slots: int, n_local: int):
    """Indexed-payload scatter-combine: the ids arrive BIT-PACKED and are
    decoded in VMEM — the receive side of the packed exchange never
    materializes int32 index rows."""
    t = pl.program_id(1)
    base = pl.program_id(0) * tile_n
    idx = _decode_packed_ids(w_ref, t, width=width, tile_t=tile_t,
                             set_slots=set_slots, n_local=n_local)
    targets = base + jax.lax.broadcasted_iota(jnp.int32, (tile_n, 1), 0)
    onehot = idx == targets                  # (TN, TI)
    ident = _identity(semiring, o_ref.dtype)
    if semiring == "plus_times":
        part = jax.lax.dot_general(
            onehot.astype(o_ref.dtype), val_ref[...].astype(o_ref.dtype),
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=o_ref.dtype,
        )                                    # (TN, 1) — MXU
    else:
        x = jnp.where(onehot, val_ref[...].astype(o_ref.dtype), ident)
        if semiring in ("min_plus", "min_src"):
            part = jnp.min(x, axis=1, keepdims=True)
        else:
            part = jnp.max(x, axis=1, keepdims=True)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = part

    @pl.when(t != 0)
    def _acc():
        o_ref[...] = _combine_all(semiring, o_ref[...], part)


def packed_scatter_combine_pallas(
    words: jnp.ndarray,
    val: jnp.ndarray,
    n_out: int,
    *,
    set_slots: int,
    n_local: int,
    width: int,
    semiring: str,
    out_dtype=None,
    tile_n: int = 128,
    tile_t: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Packed-id variant of :func:`scatter_combine_pallas`.

    words: [T * width / 32] uint32; val: [T] payload in static id order;
    slot t of set s targets row decode(t) + s*(n_local+1), s = t // set_slots.
    """
    assert semiring in SEMIRINGS
    (T,) = val.shape
    k = 32 // width
    assert T % tile_t == 0 and n_out % tile_n == 0, (T, n_out, tile_t, tile_n)
    assert tile_t % k == 0 and set_slots % k == 0, (tile_t, set_slots, k)
    assert words.shape == (T // k,), (words.shape, T, k)
    out_dtype = out_dtype or val.dtype

    grid = (n_out // tile_n, T // tile_t)
    out = pl.pallas_call(
        functools.partial(
            _packed_scatter_kernel, semiring=semiring, tile_n=tile_n,
            tile_t=tile_t, width=width, set_slots=set_slots, n_local=n_local),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_t // k), lambda i, t: (0, t)),
            pl.BlockSpec((1, tile_t), lambda i, t: (0, t)),
        ],
        out_specs=pl.BlockSpec((tile_n, 1), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, 1), out_dtype),
        interpret=interpret,
    )(words[None, :], val[None, :])
    return out[:, 0]


def _packed_scatter_multi_kernel(w_ref, val_ref, o_ref, *, semiring: str,
                                 tile_n: int, tile_t: int, width: int,
                                 set_slots: int, n_local: int):
    t = pl.program_id(2)
    base = pl.program_id(0) * tile_n
    idx = _decode_packed_ids(w_ref, t, width=width, tile_t=tile_t,
                             set_slots=set_slots, n_local=n_local)
    targets = base + jax.lax.broadcasted_iota(jnp.int32, (tile_n, 1), 0)
    onehot = idx == targets                  # (TN, TI)
    ident = _identity(semiring, o_ref.dtype)
    val = val_ref[...]                       # (TI, TQ)
    if semiring == "plus_times":
        part = jax.lax.dot_general(
            onehot.astype(o_ref.dtype), val.astype(o_ref.dtype),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=o_ref.dtype,
        )                                    # (TN, TQ) — MXU at full width
    else:
        x = jnp.where(onehot[:, :, None], val[None, :, :].astype(o_ref.dtype), ident)
        if semiring in ("min_plus", "min_src"):
            part = jnp.min(x, axis=1)
        else:
            part = jnp.max(x, axis=1)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = part

    @pl.when(t != 0)
    def _acc():
        o_ref[...] = _combine_all(semiring, o_ref[...], part)


def packed_scatter_combine_multi_pallas(
    words: jnp.ndarray,
    val: jnp.ndarray,
    n_out: int,
    *,
    set_slots: int,
    n_local: int,
    width: int,
    semiring: str,
    out_dtype=None,
    tile_n: int = 128,
    tile_t: int = 128,
    tile_q: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Multi-query packed-id scatter-combine: words [T*width/32], val [T, Q]
    -> r [n_out, Q] (the serving wire format with bit-packed structure)."""
    assert semiring in SEMIRINGS
    T, Q = val.shape
    k = 32 // width
    assert T % tile_t == 0 and n_out % tile_n == 0 and Q % tile_q == 0, (
        T, n_out, Q, tile_t, tile_n, tile_q)
    assert tile_t % k == 0 and set_slots % k == 0, (tile_t, set_slots, k)
    assert words.shape == (T // k,), (words.shape, T, k)
    out_dtype = out_dtype or val.dtype

    grid = (n_out // tile_n, Q // tile_q, T // tile_t)
    return pl.pallas_call(
        functools.partial(
            _packed_scatter_multi_kernel, semiring=semiring, tile_n=tile_n,
            tile_t=tile_t, width=width, set_slots=set_slots, n_local=n_local),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_t // k), lambda i, q, t: (0, t)),
            pl.BlockSpec((tile_t, tile_q), lambda i, q, t: (t, q)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_q), lambda i, q, t: (i, q)),
        out_shape=jax.ShapeDtypeStruct((n_out, Q), out_dtype),
        interpret=interpret,
    )(words[None, :], val)


def _scatter_combine_multi_kernel(idx_ref, val_ref, o_ref, *, semiring: str, tile_n: int):
    t = pl.program_id(2)
    base = pl.program_id(0) * tile_n
    idx = idx_ref[...]                       # (1, TI)
    targets = base + jax.lax.broadcasted_iota(jnp.int32, (tile_n, 1), 0)
    onehot = idx == targets                  # (TN, TI)
    ident = _identity(semiring, o_ref.dtype)
    val = val_ref[...]                       # (TI, TQ)
    if semiring == "plus_times":
        part = jax.lax.dot_general(
            onehot.astype(o_ref.dtype), val.astype(o_ref.dtype),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=o_ref.dtype,
        )                                    # (TN, TQ) — MXU at full width
    else:
        x = jnp.where(onehot[:, :, None], val[None, :, :].astype(o_ref.dtype), ident)
        if semiring in ("min_plus", "min_src"):
            part = jnp.min(x, axis=1)
        else:
            part = jnp.max(x, axis=1)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = part

    @pl.when(t != 0)
    def _acc():
        o_ref[...] = _combine_all(semiring, o_ref[...], part)


def scatter_combine_multi_pallas(
    idx: jnp.ndarray,
    val: jnp.ndarray,
    n_out: int,
    *,
    semiring: str,
    out_dtype=None,
    tile_n: int = 128,
    tile_t: int = 128,
    tile_q: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Multi-query scatter-combine: idx [T], val [T, Q] -> r [n_out, Q] (the
    serving wire format — Q values ride each shipped index).  The (TN, TI,
    TQ) tropical temporary bounds TQ; plus_times is a pure MXU matmul."""
    assert semiring in SEMIRINGS
    T, Q = val.shape
    assert idx.shape == (T,), (idx.shape, val.shape)
    assert T % tile_t == 0 and n_out % tile_n == 0 and Q % tile_q == 0, (
        T, n_out, Q, tile_t, tile_n, tile_q)
    out_dtype = out_dtype or val.dtype

    grid = (n_out // tile_n, Q // tile_q, T // tile_t)
    return pl.pallas_call(
        functools.partial(_scatter_combine_multi_kernel, semiring=semiring, tile_n=tile_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_t), lambda i, q, t: (0, t)),
            pl.BlockSpec((tile_t, tile_q), lambda i, q, t: (t, q)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_q), lambda i, q, t: (i, q)),
        out_shape=jax.ShapeDtypeStruct((n_out, Q), out_dtype),
        interpret=interpret,
    )(idx[None, :], val)
