"""Jit'd public wrappers for the scatter-combine kernel (pad + dispatch)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.scatter_combine.scatter_combine import (
    SEMIRINGS,
    packed_scatter_combine_multi_pallas,
    packed_scatter_combine_pallas,
    scatter_combine_multi_pallas,
    scatter_combine_pallas,
)

__all__ = ["scatter_combine_gimv", "scatter_combine_gimv_multi",
           "packed_scatter_combine_gimv", "packed_scatter_combine_gimv_multi"]


@partial(jax.jit, static_argnames=("n_out", "semiring", "tile_n", "tile_t", "interpret"))
def scatter_combine_gimv(
    idx: jnp.ndarray,
    val: jnp.ndarray,
    n_out: int,
    *,
    semiring: str,
    tile_n: int = 128,
    tile_t: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Scatter-combine with automatic tile padding.  idx/val: [T] -> [n_out].

    Pad entries (idx < 0 or idx >= n_out) contribute the combineAll identity.
    """
    assert semiring in SEMIRINGS
    (T,) = idx.shape
    Tp = max(-(-T // tile_t) * tile_t, tile_t)
    Np = -(-n_out // tile_n) * tile_n
    if Tp != T:
        idx = jnp.pad(idx, (0, Tp - T), constant_values=-1)
        val = jnp.pad(val, (0, Tp - T))
    out = scatter_combine_pallas(
        idx.astype(jnp.int32), val, Np, semiring=semiring, out_dtype=val.dtype,
        tile_n=tile_n, tile_t=tile_t, interpret=interpret)
    return out[:n_out]


@partial(jax.jit, static_argnames=("n_out", "semiring", "tile_n", "tile_t", "tile_q", "interpret"))
def scatter_combine_gimv_multi(
    idx: jnp.ndarray,
    val: jnp.ndarray,
    n_out: int,
    *,
    semiring: str,
    tile_n: int = 128,
    tile_t: int = 128,
    tile_q: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Multi-query scatter-combine with automatic tile padding.

    idx: [T], val: [T, Q] -> r [n_out, Q]."""
    assert semiring in SEMIRINGS
    T, Q = val.shape
    Tp = max(-(-T // tile_t) * tile_t, tile_t)
    Np = -(-n_out // tile_n) * tile_n
    Qp = -(-Q // tile_q) * tile_q
    if Tp != T:
        idx = jnp.pad(idx, (0, Tp - T), constant_values=-1)
        val = jnp.pad(val, ((0, Tp - T), (0, 0)))
    if Qp != Q:
        val = jnp.pad(val, ((0, 0), (0, Qp - Q)))
    out = scatter_combine_multi_pallas(
        idx.astype(jnp.int32), val, Np, semiring=semiring, out_dtype=val.dtype,
        tile_n=tile_n, tile_t=tile_t, tile_q=tile_q, interpret=interpret)
    return out[:n_out, :Q]


@partial(jax.jit, static_argnames=("n_out", "set_slots", "n_local", "width",
                                   "semiring", "tile_n", "tile_t", "interpret"))
def packed_scatter_combine_gimv(
    words: jnp.ndarray,
    val: jnp.ndarray,
    n_out: int,
    *,
    set_slots: int,
    n_local: int,
    width: int,
    semiring: str,
    tile_n: int = 128,
    tile_t: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Indexed-payload scatter-combine with automatic tile padding.

    ``words`` bit-pack the scatter targets (codec.pack_uniform at ``width``
    bits, 32/width ids per uint32); ``val`` [T] is the payload in the same
    static order.  Slot t belongs to set t // set_slots and targets row
    decode(t) + set*(n_local+1); ids >= n_local land in the set's drop slot.
    Tile padding is safe by construction: padded slots resolve to sets past
    n_out and are sliced off.
    """
    assert semiring in SEMIRINGS
    (T,) = val.shape
    k = 32 // width
    Tp = max(-(-T // tile_t) * tile_t, tile_t)
    Np = -(-n_out // tile_n) * tile_n
    if Tp != T:
        words = jnp.pad(words, (0, (Tp - T) // k))
        val = jnp.pad(val, (0, Tp - T))
    out = packed_scatter_combine_pallas(
        words.astype(jnp.uint32), val, Np, set_slots=set_slots,
        n_local=n_local, width=width, semiring=semiring, out_dtype=val.dtype,
        tile_n=tile_n, tile_t=tile_t, interpret=interpret)
    return out[:n_out]


@partial(jax.jit, static_argnames=("n_out", "set_slots", "n_local", "width",
                                   "semiring", "tile_n", "tile_t", "tile_q",
                                   "interpret"))
def packed_scatter_combine_gimv_multi(
    words: jnp.ndarray,
    val: jnp.ndarray,
    n_out: int,
    *,
    set_slots: int,
    n_local: int,
    width: int,
    semiring: str,
    tile_n: int = 128,
    tile_t: int = 128,
    tile_q: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Multi-query indexed-payload scatter-combine with tile padding.

    words: [T*width/32] uint32, val: [T, Q] -> r [n_out, Q]."""
    assert semiring in SEMIRINGS
    T, Q = val.shape
    k = 32 // width
    Tp = max(-(-T // tile_t) * tile_t, tile_t)
    Np = -(-n_out // tile_n) * tile_n
    Qp = -(-Q // tile_q) * tile_q
    if Tp != T:
        words = jnp.pad(words, (0, (Tp - T) // k))
        val = jnp.pad(val, ((0, Tp - T), (0, 0)))
    if Qp != Q:
        val = jnp.pad(val, ((0, 0), (0, Qp - Q)))
    out = packed_scatter_combine_multi_pallas(
        words.astype(jnp.uint32), val, Np, set_slots=set_slots,
        n_local=n_local, width=width, semiring=semiring, out_dtype=val.dtype,
        tile_n=tile_n, tile_t=tile_t, tile_q=tile_q, interpret=interpret)
    return out[:n_out, :Q]
