"""Jit'd public wrappers for the scatter-combine kernel (pad + dispatch)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.scatter_combine.scatter_combine import (
    SEMIRINGS,
    scatter_combine_multi_pallas,
    scatter_combine_pallas,
)

__all__ = ["scatter_combine_gimv", "scatter_combine_gimv_multi"]


@partial(jax.jit, static_argnames=("n_out", "semiring", "tile_n", "tile_t", "interpret"))
def scatter_combine_gimv(
    idx: jnp.ndarray,
    val: jnp.ndarray,
    n_out: int,
    *,
    semiring: str,
    tile_n: int = 128,
    tile_t: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Scatter-combine with automatic tile padding.  idx/val: [T] -> [n_out].

    Pad entries (idx < 0 or idx >= n_out) contribute the combineAll identity.
    """
    assert semiring in SEMIRINGS
    (T,) = idx.shape
    Tp = max(-(-T // tile_t) * tile_t, tile_t)
    Np = -(-n_out // tile_n) * tile_n
    if Tp != T:
        idx = jnp.pad(idx, (0, Tp - T), constant_values=-1)
        val = jnp.pad(val, (0, Tp - T))
    out = scatter_combine_pallas(
        idx.astype(jnp.int32), val, Np, semiring=semiring, out_dtype=val.dtype,
        tile_n=tile_n, tile_t=tile_t, interpret=interpret)
    return out[:n_out]


@partial(jax.jit, static_argnames=("n_out", "semiring", "tile_n", "tile_t", "tile_q", "interpret"))
def scatter_combine_gimv_multi(
    idx: jnp.ndarray,
    val: jnp.ndarray,
    n_out: int,
    *,
    semiring: str,
    tile_n: int = 128,
    tile_t: int = 128,
    tile_q: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Multi-query scatter-combine with automatic tile padding.

    idx: [T], val: [T, Q] -> r [n_out, Q]."""
    assert semiring in SEMIRINGS
    T, Q = val.shape
    Tp = max(-(-T // tile_t) * tile_t, tile_t)
    Np = -(-n_out // tile_n) * tile_n
    Qp = -(-Q // tile_q) * tile_q
    if Tp != T:
        idx = jnp.pad(idx, (0, Tp - T), constant_values=-1)
        val = jnp.pad(val, ((0, Tp - T), (0, 0)))
    if Qp != Q:
        val = jnp.pad(val, ((0, 0), (0, Qp - Q)))
    out = scatter_combine_multi_pallas(
        idx.astype(jnp.int32), val, Np, semiring=semiring, out_dtype=val.dtype,
        tile_n=tile_n, tile_t=tile_t, tile_q=tile_q, interpret=interpret)
    return out[:n_out, :Q]
