"""Reference (XLA segment-op) implementations for kernel parity tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scatter_combine_ref(idx: jnp.ndarray, val: jnp.ndarray, n_out: int, *,
                        semiring: str) -> jnp.ndarray:
    """Segment-op reference of scatter_combine_gimv[_multi]: out-of-range idx
    (including < 0) is dropped; empty outputs get the combineAll identity."""
    seg = jnp.where((idx >= 0) & (idx < n_out), idx, n_out)
    if semiring == "plus_times":
        op = jax.ops.segment_sum
    elif semiring in ("min_plus", "min_src"):
        op = jax.ops.segment_min
    else:
        op = jax.ops.segment_max
    return op(val, seg, num_segments=n_out + 1)[:n_out]
