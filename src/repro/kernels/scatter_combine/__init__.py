from repro.kernels.scatter_combine.ops import (
    packed_scatter_combine_gimv,
    packed_scatter_combine_gimv_multi,
    scatter_combine_gimv,
    scatter_combine_gimv_multi,
)
from repro.kernels.scatter_combine.ref import scatter_combine_ref

__all__ = ["scatter_combine_gimv", "scatter_combine_gimv_multi",
           "packed_scatter_combine_gimv", "packed_scatter_combine_gimv_multi",
           "scatter_combine_ref"]
