from repro.kernels.scatter_combine.ops import scatter_combine_gimv, scatter_combine_gimv_multi
from repro.kernels.scatter_combine.ref import scatter_combine_ref

__all__ = ["scatter_combine_gimv", "scatter_combine_gimv_multi", "scatter_combine_ref"]
