"""Jit'd public wrapper for the dense-region GIM-V kernel (pad + dispatch)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_gimv.block_gimv import SEMIRINGS, dense_gimv_multi_pallas, dense_gimv_pallas

__all__ = ["dense_gimv", "dense_gimv_multi", "semiring_of", "has_semiring"]

_SEMIRING_TABLE = {
    ("mul", "sum"): "plus_times",
    ("add", "min"): "min_plus",
    ("add", "max"): "max_plus",
    ("src", "min"): "min_src",
}


def semiring_of(combine2: str, combine_all: str) -> str:
    """Map a GimvSpec's (combine2, combineAll) to a kernel semiring id."""
    key = (combine2, combine_all)
    if key not in _SEMIRING_TABLE:
        raise ValueError(f"no kernel semiring for {key}")
    return _SEMIRING_TABLE[key]


def has_semiring(combine2: str, combine_all: str) -> bool:
    """Whether the (combine2, combineAll) pair has a Pallas kernel semiring
    (the engine's backend='pallas' falls back to 'xla' when it does not)."""
    return (combine2, combine_all) in _SEMIRING_TABLE


def _pad_identity(semiring: str, dtype):
    """Padding value for the matrix such that padded columns are no-ops."""
    if semiring == "plus_times":
        return 0
    if semiring in ("min_plus",):
        return np.inf
    if semiring == "max_plus":
        return -np.inf
    return 0  # min_src: presence 0 -> masked inside the kernel


@partial(jax.jit, static_argnames=("semiring", "tile_m", "tile_k", "tile_q", "interpret"))
def dense_gimv_multi(
    m: jnp.ndarray,
    v: jnp.ndarray,
    *,
    semiring: str,
    tile_m: int = 128,
    tile_k: int = 128,
    tile_q: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Multi-query dense block GIM-V with automatic tile padding.

    m: [M, K], v: [K, Q] -> r: [M, Q].  plus_times defaults to a 128-wide
    query tile (full MXU); the tropical semirings default to TQ=8 so their
    (TM, TK, TQ) broadcast temporary stays ~512 KB of VMEM.
    """
    assert semiring in SEMIRINGS
    if tile_q is None:
        tile_q = 128 if semiring == "plus_times" else 8
    M, K = m.shape
    _, Q = v.shape
    Mp = -(-M // tile_m) * tile_m
    Kp = -(-K // tile_k) * tile_k
    Qp = -(-Q // tile_q) * tile_q
    if (Mp, Kp) != (M, K):
        pad_val = _pad_identity(semiring, m.dtype)
        m = jnp.pad(m, ((0, Mp - M), (0, Kp - K)), constant_values=pad_val)
    if (Kp, Qp) != (K, Q):
        # Padded K rows are never selected (matrix padding is the identity);
        # padded Q columns are sliced off below.
        v = jnp.pad(v, ((0, Kp - K), (0, Qp - Q)))
    out = dense_gimv_multi_pallas(
        m, v, semiring=semiring, out_dtype=v.dtype,
        tile_m=tile_m, tile_k=tile_k, tile_q=tile_q, interpret=interpret,
    )
    return out[:M, :Q]


@partial(jax.jit, static_argnames=("semiring", "tile_m", "tile_k", "interpret"))
def dense_gimv(
    m: jnp.ndarray,
    v: jnp.ndarray,
    *,
    semiring: str,
    tile_m: int = 128,
    tile_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Dense block GIM-V with automatic tile padding.  m: [M, K], v: [K]."""
    assert semiring in SEMIRINGS
    M, K = m.shape
    Mp = -(-M // tile_m) * tile_m
    Kp = -(-K // tile_k) * tile_k
    if (Mp, Kp) != (M, K):
        pad_val = _pad_identity(semiring, m.dtype)
        m = jnp.pad(m, ((0, Mp - M), (0, Kp - K)), constant_values=pad_val)
        # Padded v entries are never selected: matrix padding is the identity.
        v = jnp.pad(v, (0, Kp - K))
    out = dense_gimv_pallas(
        m, v, semiring=semiring, out_dtype=v.dtype,
        tile_m=tile_m, tile_k=tile_k, interpret=interpret,
    )
    return out[:M]
