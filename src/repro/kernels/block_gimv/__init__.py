from repro.kernels.block_gimv.ops import dense_gimv, dense_gimv_multi, has_semiring, semiring_of
from repro.kernels.block_gimv.ref import dense_gimv_multi_ref, dense_gimv_ref

__all__ = ["dense_gimv", "dense_gimv_multi", "dense_gimv_multi_ref", "dense_gimv_ref",
           "has_semiring", "semiring_of"]
