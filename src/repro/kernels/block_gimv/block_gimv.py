"""Pallas TPU kernel: dense-region block GIM-V (the paper's M_d (x) v_d).

PMV_hybrid executes the dense region (columns of high-out-degree vertices)
horizontally: every worker holds the gathered dense sub-vector v_d and its
dense row stripe.  When that stripe is materialized as an actual dense
matrix (rows = local vertices, cols = compacted dense slots), the semiring
"matvec" is a classic MXU/VPU tiling problem:

- (x, +)  [PageRank / RWR]: real matmul -> `jnp.dot` on the MXU.
- (+, min) [SSSP]:          broadcast-add + row-min on the VPU.
- (src, min) [CC]:          presence-masked select + row-min on the VPU.

Grid = (row_tiles, col_tiles); the output row tile is revisited along the
col grid axis and accumulated in place with the semiring's combineAll —
the standard TPU reduction pattern (output VMEM block as accumulator).
Tiles are MXU/VPU aligned: TM rows x TK cols, both multiples of 128 (8 is
the sublane minimum for f32; we use 128 to keep the MXU fed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SEMIRINGS = ("plus_times", "min_plus", "min_src", "max_plus")


def _combine_all(semiring: str, a, b):
    if semiring == "plus_times":
        return a + b
    if semiring in ("min_plus", "min_src"):
        return jnp.minimum(a, b)
    return jnp.maximum(a, b)


def _identity(semiring: str, dtype):
    if semiring == "plus_times":
        return jnp.zeros((), dtype)
    if semiring in ("min_plus", "min_src"):
        return jnp.array(jnp.inf if jnp.issubdtype(dtype, jnp.floating) else jnp.iinfo(dtype).max, dtype)
    return jnp.array(-jnp.inf if jnp.issubdtype(dtype, jnp.floating) else jnp.iinfo(dtype).min, dtype)


def _dense_gimv_kernel(m_ref, v_ref, o_ref, *, semiring: str):
    """One (TM, TK) tile: partial combineAll over the TK columns."""
    k = pl.program_id(1)
    m = m_ref[...]                      # (TM, TK) matrix values
    v = v_ref[...]                      # (1, TK) vector tile

    if semiring == "plus_times":
        part = jax.lax.dot_general(
            m, v,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=o_ref.dtype,
        )                               # (TM, 1) — MXU
    elif semiring == "min_plus":
        part = jnp.min(m + v, axis=1, keepdims=True)
    elif semiring == "max_plus":
        part = jnp.max(m + v, axis=1, keepdims=True)
    else:  # min_src: m is a presence indicator; absent -> identity
        ident = _identity(semiring, o_ref.dtype)
        x = jnp.where(m > 0, v.astype(o_ref.dtype), ident)
        part = jnp.min(x, axis=1, keepdims=True)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part.astype(o_ref.dtype)

    @pl.when(k != 0)
    def _acc():
        o_ref[...] = _combine_all(semiring, o_ref[...], part.astype(o_ref.dtype))


def _dense_gimv_multi_kernel(m_ref, v_ref, o_ref, *, semiring: str):
    """One (TM, TK) x (TK, TQ) tile: partial combineAll over the TK columns.

    plus_times is a straight MXU matmul; the tropical semirings broadcast to
    a (TM, TK, TQ) tile in VMEM and reduce on the VPU — ops.py keeps TQ small
    for those so the 3-D temporary fits.
    """
    k = pl.program_id(2)
    m = m_ref[...]                      # (TM, TK) matrix values
    v = v_ref[...]                      # (TK, TQ) query-tile of vectors

    if semiring == "plus_times":
        part = jax.lax.dot_general(
            m, v,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=o_ref.dtype,
        )                               # (TM, TQ) — MXU at full width
    elif semiring == "min_plus":
        part = jnp.min(m[:, :, None] + v[None, :, :], axis=1)
    elif semiring == "max_plus":
        part = jnp.max(m[:, :, None] + v[None, :, :], axis=1)
    else:  # min_src: m is a presence indicator; absent -> identity
        ident = _identity(semiring, o_ref.dtype)
        x = jnp.where(m[:, :, None] > 0, v[None, :, :].astype(o_ref.dtype), ident)
        part = jnp.min(x, axis=1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part.astype(o_ref.dtype)

    @pl.when(k != 0)
    def _acc():
        o_ref[...] = _combine_all(semiring, o_ref[...], part.astype(o_ref.dtype))


def dense_gimv_multi_pallas(
    m: jnp.ndarray,
    v: jnp.ndarray,
    *,
    semiring: str,
    out_dtype=None,
    tile_m: int = 128,
    tile_k: int = 128,
    tile_q: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Multi-query semiring matmul r = M (x) V over a dense block.

    m: [M, K] (values; for min_src a presence matrix), v: [K, Q] — one query
    per column.  The grid gains a query-tile axis so the MXU (plus_times) /
    VPU (tropical) is fed TQ queries wide per pass over the resident matrix
    tile — the batched-serving analog of dense_gimv_pallas.  M, K, Q must be
    multiples of the tile sizes (ops.py pads).  Returns r: [M, Q].
    """
    assert semiring in SEMIRINGS, semiring
    M, K = m.shape
    K2, Q = v.shape
    assert K2 == K, (m.shape, v.shape)
    assert M % tile_m == 0 and K % tile_k == 0 and Q % tile_q == 0, (
        M, K, Q, tile_m, tile_k, tile_q)
    out_dtype = out_dtype or v.dtype

    grid = (M // tile_m, Q // tile_q, K // tile_k)  # k innermost: accumulate
    return pl.pallas_call(
        functools.partial(_dense_gimv_multi_kernel, semiring=semiring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda i, q, k: (i, k)),
            pl.BlockSpec((tile_k, tile_q), lambda i, q, k: (k, q)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_q), lambda i, q, k: (i, q)),
        out_shape=jax.ShapeDtypeStruct((M, Q), out_dtype),
        interpret=interpret,
    )(m, v)


def dense_gimv_pallas(
    m: jnp.ndarray,
    v: jnp.ndarray,
    *,
    semiring: str,
    out_dtype=None,
    tile_m: int = 128,
    tile_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """r = combineAll_j combine2(m[:, j], v[j]) over a dense block.

    m: [M, K] (values; for min_src a presence matrix), v: [K].
    M, K must be multiples of the tile sizes (ops.py pads).
    Returns r: [M].
    """
    assert semiring in SEMIRINGS, semiring
    M, K = m.shape
    assert v.shape == (K,), (m.shape, v.shape)
    assert M % tile_m == 0 and K % tile_k == 0, (M, K, tile_m, tile_k)
    out_dtype = out_dtype or v.dtype

    grid = (M // tile_m, K // tile_k)
    out = pl.pallas_call(
        functools.partial(_dense_gimv_kernel, semiring=semiring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda i, k: (i, k)),
            pl.BlockSpec((1, tile_k), lambda i, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((tile_m, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, 1), out_dtype),
        interpret=interpret,
    )(m, v[None, :])
    return out[:, 0]
