"""Pure-jnp oracle for the dense-region block GIM-V kernel."""
from __future__ import annotations

import jax.numpy as jnp


def dense_gimv_ref(m: jnp.ndarray, v: jnp.ndarray, *, semiring: str, out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or v.dtype
    if semiring == "plus_times":
        return (m @ v.astype(m.dtype)).astype(out_dtype)
    if semiring == "min_plus":
        return jnp.min(m + v[None, :], axis=1).astype(out_dtype)
    if semiring == "max_plus":
        return jnp.max(m + v[None, :], axis=1).astype(out_dtype)
    if semiring == "min_src":
        ident = jnp.inf if jnp.issubdtype(jnp.dtype(out_dtype), jnp.floating) else jnp.iinfo(out_dtype).max
        x = jnp.where(m > 0, v[None, :].astype(out_dtype), jnp.array(ident, out_dtype))
        return jnp.min(x, axis=1)
    raise ValueError(semiring)


def dense_gimv_multi_ref(m: jnp.ndarray, v: jnp.ndarray, *, semiring: str, out_dtype=None) -> jnp.ndarray:
    """Vmapped oracle for the multi-query kernel: m [M, K], v [K, Q] -> [M, Q]."""
    import jax

    return jax.vmap(
        lambda col: dense_gimv_ref(m, col, semiring=semiring, out_dtype=out_dtype),
        in_axes=1, out_axes=1,
    )(v)
