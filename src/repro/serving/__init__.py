"""repro.serving: batched multi-query GIM-V serving (continuous batching).

Pre-partition once, answer many concurrent queries against the resident
matrix — see server.py for the design notes.
"""
from repro.serving.batcher import DEFAULT_BUCKETS, Query, QueryBatcher, QueryResult
from repro.serving.server import FAMILIES, PMVServer, QueryFamily, make_batched_step, per_query_delta

__all__ = [
    "DEFAULT_BUCKETS",
    "FAMILIES",
    "PMVServer",
    "Query",
    "QueryBatcher",
    "QueryFamily",
    "QueryResult",
    "make_batched_step",
    "per_query_delta",
]
