"""PMVServer: pre-partition once, answer many concurrent GIM-V queries.

The paper amortizes pre-partitioning across the *iterations* of one solve
(§3.1); serving amortizes it across *queries*.  The resident matrix stripes
stay on device while query vectors come and go as columns of a blocked
[b, n_local, Q] batch — every placement (placement.py) carries the trailing
query axis through its collectives, so one iteration of the batched step
advances all in-flight queries at the cost of one matrix traversal.

Continuous batching: each query column tracks its own convergence delta; a
converged column is retired (result extracted, latency recorded) and a
waiting query of the same family is admitted into the freed column mid-loop
without disturbing the others — the GIM-V semirings are columnwise
independent, so an admitted column's trajectory is bitwise the trajectory it
would have had in a fresh batch.  Batches are padded to fixed Q buckets
(batcher.py) so jit specializes once per bucket size.

Degradation under pressure (ISSUE 7): per-query ``deadline_s`` budgets
(anchored at submit — an expired column retires with its partial iterate),
``max_queue`` admission control (overloaded submits shed immediately instead
of growing every deadline behind them), and batch-level failure containment
(an I/O / integrity error that survives the retry layer fails THAT batch's
queries with a typed diagnosis; the server keeps serving).  Every retirement
carries a reason — completed | deadline_exceeded | shed | failed — tallied
in ``stats()['retirement_reasons']``.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms
from repro.core.engine import PMVEngine, StepConfig, _squeeze0, placement_call
from repro.core.gimv import GimvSpec
from repro.faults import FetchDeadlineError, as_injector
from repro.obs import as_recorder, as_telemetry
from repro.serving.batcher import (
    DEFAULT_BUCKETS,
    RETIREMENT_REASONS,
    Query,
    QueryBatcher,
    QueryResult,
)

__all__ = ["PMVServer", "QueryFamily", "FAMILIES", "make_batched_step", "per_query_delta"]


# ---------------------------------------------------------------------------
# Query families: algorithm kind -> spec + per-query column construction.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QueryFamily:
    """How to turn queries of one kind into columns of a batched solve.

    delta_kind: 'abs' (sum |dv|, the PR/RWR metric) or 'count' (changed
      entries — SSSP/CC, whose +-inf distances make abs-deltas NaN).
    empty_column: neutral fill for padded / retired-and-unreplaced columns;
      frozen by the active mask but must stay finite under combine2.
    """

    kind: str
    delta_kind: str
    make_spec: Callable[[int, Query], GimvSpec]
    init_column: Callable[[int, Query], np.ndarray]
    ctx_columns: Callable[[int, Query], dict[str, np.ndarray]]
    empty_column: Callable[[int], np.ndarray]
    symmetrize: bool = False


def _onehot(n: int, i: int) -> np.ndarray:
    x = np.zeros(n, np.float32)
    x[i] = 1.0
    return x


FAMILIES: dict[str, QueryFamily] = {
    "pagerank": QueryFamily(
        kind="pagerank",
        delta_kind="abs",
        make_spec=lambda n, q: algorithms.pagerank(n, damping=q.c),
        init_column=lambda n, q: np.full(n, 1.0 / n, np.float32),
        ctx_columns=lambda n, q: {},
        empty_column=lambda n: np.zeros(n, np.float32),
    ),
    "rwr": QueryFamily(
        kind="rwr",
        delta_kind="abs",
        make_spec=lambda n, q: algorithms.random_walk_with_restart(n, source=q.source, c=q.c),
        init_column=lambda n, q: _onehot(n, q.source),
        ctx_columns=lambda n, q: algorithms.rwr_context(n, q.source),
        empty_column=lambda n: np.zeros(n, np.float32),
    ),
    "sssp": QueryFamily(
        kind="sssp",
        delta_kind="count",
        make_spec=lambda n, q: algorithms.sssp(source=q.source),
        init_column=lambda n, q: np.where(np.arange(n) == q.source, np.float32(0.0), np.float32(np.inf)),
        ctx_columns=lambda n, q: {},
        empty_column=lambda n: np.full(n, np.inf, np.float32),
    ),
    "cc": QueryFamily(
        kind="cc",
        delta_kind="count",
        make_spec=lambda n, q: algorithms.connected_components(),
        init_column=lambda n, q: np.arange(n, dtype=np.int32),
        ctx_columns=lambda n, q: {},
        empty_column=lambda n: np.arange(n, dtype=np.int32),
        symmetrize=True,
    ),
}


# ---------------------------------------------------------------------------
# Batched step: placement with a trailing query axis + per-query convergence.
# ---------------------------------------------------------------------------

def per_query_delta(v, v_new, *, delta_kind: str):
    """Per-column convergence contribution: [.., n_local, Q] -> [Q]."""
    axes = tuple(range(v_new.ndim - 1))
    if delta_kind == "count":
        return jnp.sum((v_new != v).astype(jnp.float32), axis=axes)
    return jnp.sum(jnp.abs(v_new - v), axis=axes)


def make_batched_step(spec: GimvSpec, cfg: StepConfig, mesh=None, axis_name: str = "workers",
                      *, delta_kind: str = "abs"):
    """Build step(matrix, v, ctx, mask, active) -> (v_new, deltas [Q], stats).

    v/ctx carry a trailing query axis ([b, n_local, Q] in emulation,
    [n_local, Q] per worker in SPMD).  ``active`` [Q] freezes retired /
    padded columns: their v entries pass through unchanged, so a column can
    sit retired while the rest of the batch keeps iterating.
    """

    def _advance(matrix, v, ctx, mask, active, axis):
        v_new, _r, stats = placement_call(spec, cfg, matrix, v, ctx, mask, axis)
        v_new = jnp.where(active, v_new, v)  # broadcast over trailing Q axis
        return v_new, per_query_delta(v, v_new, delta_kind=delta_kind), stats

    if mesh is None:
        def step(matrix, v, ctx, mask, active):
            return _advance(matrix, v, ctx, mask, active, None)
        return jax.jit(step, donate_argnums=(1,))

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(matrix, v, ctx, mask, active):
        matrix_, v_, ctx_, mask_ = (_squeeze0(t) for t in (matrix, v, ctx, mask))
        v_new, deltas, stats = _advance(matrix_, v_, ctx_, mask_, active, axis_name)
        deltas = jax.lax.psum(deltas, axis_name)
        return v_new[None], deltas, stats

    sharded, repl = P(axis_name), P()
    step = shard_map(
        body, mesh=mesh,
        in_specs=(sharded, sharded, sharded, sharded, repl),
        out_specs=(sharded, repl, repl),
        check_rep=False,
    )
    return jax.jit(step, donate_argnums=(1,))


def _make_disk_batched_step(executor, *, delta_kind: str):
    """Batched step over an out-of-core store (residency='disk'): the
    DiskExecutor walks the launch schedule exactly as in the scalar path —
    the trailing query axis rides through single_block_compact's batched
    compaction — and only the active-column freeze + per-query deltas are
    applied here."""

    @partial(jax.jit, donate_argnums=())
    def _freeze(v, v_cand, active):
        v_new = jnp.where(active, v_cand, v)
        return v_new, per_query_delta(v, v_new, delta_kind=delta_kind)

    def step(matrix, v, ctx, mask, active):
        del matrix
        v_cand, _delta, stats = executor.iteration(v, ctx, mask)
        v_new, deltas = _freeze(v, v_cand, active)
        return v_new, deltas, stats

    return step


@partial(jax.jit, donate_argnums=(0, 1))
def _admit_columns(v, ctx, slot_idx, v_cols, ctx_cols):
    """Admit one iteration's queries in a single donated scatter.

    v: [b, n_local, Q] (donated — updated in place on device), slot_idx: [k]
    freed column indices, v_cols: [b, n_local, k] init columns.  Batching the
    admissions and donating the buffers replaces the per-query eager
    ``.at[].set`` (which copied the full multi-GB state once per admitted
    query) with one fused scatter per iteration.
    """
    v = v.at[:, :, slot_idx].set(v_cols)
    ctx = {k: ctx[k].at[:, :, slot_idx].set(ctx_cols[k]) for k in ctx}
    return v, ctx


# ---------------------------------------------------------------------------
# The server.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _FamilyState:
    family: QueryFamily
    spec: GimvSpec
    engine: PMVEngine
    step: Callable
    matrix: object
    mask: object
    part: object
    meta: dict


class PMVServer:
    """Multi-query GIM-V serving over one resident pre-partitioned matrix.

    submit() enqueues queries; drain() packs them into Q-bucket batches per
    family, iterates the batched step with per-query convergence tracking,
    and continuously admits waiting queries into retired columns.  Everything
    expensive — partitioning, device-resident stripes, jit — is cached per
    family across batches (and across drain calls).
    """

    def __init__(
        self,
        edges: np.ndarray | None = None,
        n: int | None = None,
        *,
        b: int | None = None,
        strategy: str = "selective",
        theta: float | str = "auto",
        psi: str | None = None,  # None: 'cyclic', or the store's ψ
        exchange: str = "sparse",
        capacity: str = "structural",
        slack: float = 1.5,
        payload_dtype: str | None = None,
        backend: str = "xla",
        scatter: str = "auto",
        stream: str = "auto",
        pallas_interpret: bool | None = None,
        base_weights: np.ndarray | None = None,
        buckets: tuple[int, ...] = DEFAULT_BUCKETS,
        max_iters: int = 200,
        mesh=None,
        axis_name: str = "workers",
        store=None,
        residency: str = "device",
        store_budget_bytes: int | None = None,
        obs=None,
        faults=None,
        io_retry=None,
        max_queue: int | None = None,
        telemetry=None,
    ):
        self.store = None
        self.residency = residency
        self.store_budget_bytes = store_budget_bytes
        if store is not None:
            # manifest-backed serving: the resident matrix comes from an
            # ingested block store (path or Manifest); n/b/psi are its.
            from repro.store import open_store

            self.store = open_store(store)
            if edges is not None:
                raise ValueError("pass either edges or store=, not both")
            if n is not None and int(n) != self.store.n:
                raise ValueError(f"n={n} does not match the store's n={self.store.n}")
            if b is not None and int(b) != self.store.b:
                raise ValueError(f"b={b} does not match the store's b={self.store.b}")
            n, b = self.store.n, self.store.b
            self.edges = None
        else:
            if edges is None or n is None or b is None:
                raise ValueError("PMVServer needs (edges, n, b=) or store=")
            self.edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        self.n = int(n)
        self.b = int(b)
        self.max_iters = int(max_iters)
        self.mesh = mesh
        self.axis_name = axis_name
        # obs is shared with every family engine (and through it the disk
        # executor/store), so one recorder traces the whole serving run.
        self.obs = as_recorder(obs)
        self._engine_kwargs = dict(
            strategy=strategy, theta=theta, psi=psi, exchange=exchange,
            capacity=capacity, slack=slack, payload_dtype=payload_dtype,
            backend=backend, scatter=scatter, stream=stream,
            pallas_interpret=pallas_interpret,
            base_weights=base_weights, mesh=mesh, axis_name=axis_name,
            # normalized ONCE so every family engine shares one injector —
            # a FaultPlan's events fire once server-wide, not once per family
            obs=self.obs, faults=as_injector(faults, self.obs),
            io_retry=io_retry,
        )
        # admission control: queries submitted while >= max_queue are waiting
        # are shed immediately (reason='shed') instead of growing the backlog
        # without bound.  None = accept everything (the default).
        self.max_queue = max_queue
        # live telemetry: rolling-window latency/throughput + SLO burn rates
        # over the retirement ledger, optionally exported over HTTP
        # (repro.obs.live).  Host-side bookkeeping only — cannot change a
        # served result.  True -> defaults; TelemetryConfig / LiveTelemetry
        # accepted; None/False -> off.
        self.telemetry = as_telemetry(
            telemetry, registry=self.obs.metrics if self.obs.enabled else None)
        if self.telemetry is not None and self.telemetry.config.serve:
            self.telemetry.start_server()
        self._batcher = QueryBatcher(buckets)
        self._families: dict[tuple, _FamilyState] = {}
        self._family_overrides: dict[tuple, dict] = {}  # overflow fallbacks
        self._results: dict[int, QueryResult] = {}
        self._next_qid = 0
        self._fallback_events: list[str] = []  # fallback labels, batch order
        self._occupancy_sum = 0.0              # sum over batches of |queries|/Q
        self._retirement_reasons = {r: 0 for r in RETIREMENT_REASONS}
        self._stats = {
            "batches": 0, "queries": 0, "admitted_mid_batch": 0,
            "overflow_fallbacks": 0, "retired": 0, "requeued": 0,
            "shed": 0, "failed_batches": 0,
            "queue_wait_s": 0.0,
            "iterations": 0.0, "gathered_elems": 0.0, "exchanged_elems": 0.0,
            "logical_elems": 0.0, "wall_s": 0.0,
        }

    # ------------------------------------------------------------------
    def submit(self, query: Query) -> int:
        """Enqueue a query; returns its qid (key into drain()'s results).

        Load shedding: when ``max_queue`` is set and that many queries are
        already waiting, the query is refused up front — drain() returns a
        ``reason='shed'`` result for its qid (vector None) instead of letting
        the backlog (and every deadline behind it) grow without bound.
        """
        if not 0 <= query.source < self.n:
            raise ValueError(
                f"query source {query.source} out of range for |V|={self.n}")
        if query.qid is not None:  # resubmission: don't alias the old entry
            query = dataclasses.replace(query, qid=None, t_submit=None)
        qid = self._next_qid
        self._next_qid += 1
        query.qid = qid
        query.t_submit = time.perf_counter()
        self._stats["queries"] += 1
        if self.max_queue is not None and len(self._batcher) >= self.max_queue:
            self._retire_unserved(query, "shed")
            self._stats["shed"] += 1
            self.obs.counter("serve.shed").add(1)
            return qid
        self._batcher.add(query)
        if self.telemetry is not None:
            self.telemetry.record_queue_depth(len(self._batcher))
        return qid

    def _retire_unserved(self, query: Query, reason: str,
                         error: str | None = None) -> None:
        """Record a result for a query whose column never (or no longer)
        iterates: shed at admission or lost to a failed batch."""
        latency = time.perf_counter() - query.t_submit
        self._results[query.qid] = QueryResult(
            qid=query.qid, query=query, vector=None, iterations=0,
            converged=False, latency_s=latency,
            reason=reason, error=error,
        )
        self._retirement_reasons[reason] += 1
        if self.telemetry is not None:
            self.telemetry.record_retirement(
                reason, latency, had_deadline=query.deadline_s is not None)

    def drain(self) -> dict[int, QueryResult]:
        """Serve every queued query to convergence; returns {qid: result}."""
        while True:
            nxt = self._batcher.next_batch()
            if nxt is None:
                break
            key, batch = nxt
            self._run_batch(key, batch)
        out, self._results = self._results, {}
        return out

    def serve(self, queries: list[Query]) -> list[QueryResult]:
        """submit() + drain(), results in submission order."""
        qids = [self.submit(q) for q in queries]
        results = self.drain()
        return [results[qid] for qid in qids]

    def stats(self) -> dict:
        """Serving counters: batches/queries/iterations plus the retirement
        ledger — ``retired`` answered columns, ``requeued`` queries sent back
        through the batcher by an overflow fallback, ``fallback_events``
        (the fallback labels, batch order), total ``queue_wait_s`` and mean
        ``batch_occupancy`` (real queries / bucket capacity)."""
        out = dict(self._stats)
        out["fallback_events"] = list(self._fallback_events)
        out["retirement_reasons"] = dict(self._retirement_reasons)
        out["batch_occupancy"] = (
            self._occupancy_sum / out["batches"] if out["batches"] else 0.0)
        if self.telemetry is not None:
            out["slo"] = self.telemetry.slo.snapshot()
        return out

    def close(self) -> None:
        """Release resources held beyond the serve loop (today: the
        telemetry HTTP exporter's daemon thread, if one was started)."""
        if self.telemetry is not None:
            self.telemetry.close()

    # ------------------------------------------------------------------
    def _family_state(self, key: tuple, sample: Query) -> _FamilyState:
        if key not in self._families:
            family = FAMILIES[sample.spec_kind]
            spec = family.make_spec(self.n, sample)
            kwargs = dict(self._engine_kwargs)
            kwargs.update(self._family_overrides.get(key, {}))
            if self.store is not None:
                if family.symmetrize and not self.store.symmetrized:
                    raise ValueError(
                        f"query family {family.kind!r} needs a symmetrized "
                        "graph but the store was ingested without symmetrize "
                        "— re-ingest with ingest_edges(symmetrize=True)")
                engine = PMVEngine(
                    None, store=self.store, residency=self.residency,
                    store_budget_bytes=self.store_budget_bytes,
                    symmetrize=family.symmetrize, **kwargs)
            else:
                engine = PMVEngine(self.edges, self.n, b=self.b,
                                   symmetrize=family.symmetrize, **kwargs)
            _, matrix, _v0, _ctx, mask, meta = engine.prepare(spec)
            if meta.get("residency") == "disk":
                step = _make_disk_batched_step(meta["executor"],
                                               delta_kind=family.delta_kind)
            else:
                step = make_batched_step(spec, meta["cfg"], self.mesh,
                                         self.axis_name,
                                         delta_kind=family.delta_kind)
            self._families[key] = _FamilyState(
                family=family, spec=spec, engine=engine, step=step,
                matrix=matrix, mask=mask, part=meta["part"], meta=meta,
            )
        return self._families[key]

    def _column(self, st: _FamilyState, query: Query | None):
        """(v_col [b, n_local], ctx cols) for a query (None -> neutral pad)."""
        fam, part = st.family, st.part
        if query is None:
            v_col = part.to_blocked(fam.empty_column(self.n))
            ctx_cols = {k: np.zeros((self.b, part.n_local), x.dtype) for k, x in
                        fam.ctx_columns(self.n, Query(spec_kind=fam.kind)).items()}
        else:
            v_col = part.to_blocked(fam.init_column(self.n, query))
            ctx_cols = {k: part.to_blocked(x) for k, x in fam.ctx_columns(self.n, query).items()}
        return v_col, ctx_cols

    def _run_batch(self, key: tuple, batch: list[Query]) -> None:
        from repro.store.manifest import ShardCorruptError

        obs = self.obs
        with obs.span("serve.batch") as batch_span:
            batch_span.set("family", str(key))
            try:
                self._run_batch_inner(key, batch, batch_span)
            except (ShardCorruptError, OSError, FetchDeadlineError) as e:
                # The I/O / integrity layer exhausted its retries: this batch
                # is lost, but the SERVER is not — every unanswered query in
                # it retires with reason='failed' and the typed diagnosis, and
                # later batches (other families, re-ingested stores) proceed.
                self._stats["failed_batches"] += 1
                obs.counter("serve.failed_batches").add(1)
                batch_span.set("failed", type(e).__name__)
                self._families.pop(key, None)  # state may be half-built
                for query in batch:
                    if query.qid not in self._results:
                        self._retire_unserved(query, "failed", error=str(e))

    def _run_batch_inner(self, key: tuple, batch: list[Query], batch_span) -> None:
        obs = self.obs
        st = self._family_state(key, batch[0])
        part = st.part
        n_q = self._batcher.bucket_for(len(batch))
        self._stats["batches"] += 1
        self._occupancy_sum += len(batch) / n_q
        obs.gauge("serve.batch_occupancy").set(len(batch) / n_q)
        batch_span.set("n_q", n_q)
        batch_span.set("queries", len(batch))

        slots: list[Query | None] = [None] * n_q
        v_np = np.zeros((self.b, part.n_local, n_q), st.spec.dtype)
        ctx_np: dict[str, np.ndarray] | None = None
        for q_i in range(n_q):
            query = batch[q_i] if q_i < len(batch) else None
            slots[q_i] = query
            v_col, ctx_cols = self._column(st, query)
            if ctx_np is None:
                ctx_np = {k: np.zeros((self.b, part.n_local, n_q), x.dtype)
                          for k, x in ctx_cols.items()}
            v_np[:, :, q_i] = v_col
            for k, x in ctx_cols.items():
                ctx_np[k][:, :, q_i] = x

        v = jnp.asarray(v_np)
        ctx = {k: jnp.asarray(x) for k, x in (ctx_np or {}).items()}
        active = np.array([s is not None for s in slots])
        iters = np.zeros(n_q, np.int64)
        tols = np.array([s.tol if s else 0.0 for s in slots])
        caps = np.array([(s.max_iters or self.max_iters) if s else 0 for s in slots])
        # absolute per-query deadlines (inf = none), anchored at SUBMIT time:
        # queue wait counts against the budget, as a caller's SLO would.
        dls = np.array([(s.t_submit + s.deadline_s)
                        if s is not None and s.deadline_s is not None
                        else np.inf for s in slots])
        # queue wait ends when a query's column starts iterating: now for the
        # initial slots, the admission instant for mid-batch admissions.
        t_start = time.perf_counter()
        starts = np.full(n_q, t_start)

        while active.any():
            t0 = time.perf_counter()
            with obs.span("serve.iteration") as sp:
                v_new, deltas, stats = st.step(st.matrix, v, ctx, st.mask, jnp.asarray(active))
                v_new = obs.fence(v_new)
                deltas = np.asarray(deltas)
                sp.set("active", int(active.sum()))
            iter_wall = time.perf_counter() - t0
            self._stats["wall_s"] += iter_wall
            self._stats["iterations"] += 1
            if self.telemetry is not None:
                self.telemetry.record_iteration(iter_wall,
                                                active=int(active.sum()))
                self.telemetry.record_queue_depth(len(self._batcher))
            for k in ("gathered_elems", "exchanged_elems", "logical_elems"):
                self._stats[k] += float(np.asarray(stats.get(k, 0.0)))
            if float(np.asarray(stats.get("overflow", 0.0))) > 0:
                # A truncated exchange would silently corrupt EVERY in-flight
                # column (the shared index set unions rows across queries), so
                # the truncated iteration is discarded.  When an overflow-free
                # configuration exists (the engine's fallback table: vertical
                # -> dense exchange, hybrid -> structural capacity), the
                # family is rebuilt with it and the batch's in-flight queries
                # are requeued — they restart, but keep their qids so callers
                # see answers, not errors.  The default capacity='structural'
                # cannot overflow.
                fb = st.engine.fallback_overrides(st.meta["strategy"])
                if fb is None:
                    lost = sorted(q.qid for q in slots if q is not None)
                    raise RuntimeError(
                        "sparse exchange overflow in batched serving: capacity "
                        f"{st.meta['capacity']} too small for the query batch — "
                        "construct the server with capacity='structural' or "
                        f"exchange='dense'; unanswered qids in this batch: {lost}")
                label, overrides = fb
                self._stats["overflow_fallbacks"] += 1
                self._fallback_events.append(label)
                obs.counter("serve.fallbacks").add(1)
                batch_span.set("fallback", label)
                self._family_overrides[key] = {**self._family_overrides.get(key, {}),
                                               **overrides}
                del self._families[key]  # rebuilt with the fallback on requeue
                for query in slots:
                    if query is not None:
                        self._batcher.add(query)  # keeps qid -> result mapping
                        self._stats["requeued"] += 1
                return
            iters[active] += 1

            admissions: list[tuple[int, np.ndarray, dict]] = []
            now = time.perf_counter()
            for q_i in np.nonzero(active)[0]:
                done = deltas[q_i] < tols[q_i]
                expired = not done and now > dls[q_i]
                if not done and not expired and iters[q_i] < caps[q_i]:
                    continue
                # retire the converged / capped / deadline-expired column.
                # An expired query still gets its PARTIAL iterate back —
                # the caller asked for the best answer by the deadline.
                query = slots[q_i]
                reason = "deadline_exceeded" if expired else "completed"
                vec = part.from_blocked(np.asarray(v_new[:, :, q_i]))
                latency = time.perf_counter() - query.t_submit
                self._results[query.qid] = QueryResult(
                    qid=query.qid, query=query, vector=vec,
                    iterations=int(iters[q_i]), converged=bool(done),
                    latency_s=latency, reason=reason,
                )
                self._retirement_reasons[reason] += 1
                if expired:
                    obs.counter("serve.deadline_exceeded").add(1)
                self._stats["retired"] += 1
                wait = max(0.0, starts[q_i] - query.t_submit)
                self._stats["queue_wait_s"] += wait
                if self.telemetry is not None:
                    self.telemetry.record_retirement(
                        reason, latency, queue_wait_s=wait,
                        had_deadline=query.deadline_s is not None)
                if obs.enabled:
                    obs.counter("serve.retired").add(1)
                    obs.histogram("serve.query_latency_s").observe(latency)
                    obs.histogram("serve.queue_wait_s").observe(wait)
                    obs.histogram("serve.query_iterations").observe(int(iters[q_i]))
                # admit a waiting query of the same family into the freed slot
                waiting = self._batcher.pop_waiting(key)
                if waiting is not None:
                    self._stats["admitted_mid_batch"] += 1
                    batch.append(waiting)  # a later batch failure must see it
                    slots[q_i] = waiting
                    v_col, ctx_cols = self._column(st, waiting)
                    admissions.append((int(q_i), v_col, ctx_cols))
                    iters[q_i] = 0
                    tols[q_i] = waiting.tol
                    caps[q_i] = waiting.max_iters or self.max_iters
                    dls[q_i] = (waiting.t_submit + waiting.deadline_s
                                if waiting.deadline_s is not None else np.inf)
                    starts[q_i] = time.perf_counter()
                else:
                    slots[q_i] = None
                    active[q_i] = False
            if admissions:
                # one jitted, buffer-donated scatter admits the whole
                # iteration's queries (vs an eager full-state copy per query)
                slot_idx = np.array([a[0] for a in admissions], np.int32)
                v_cols = np.stack([a[1] for a in admissions], axis=-1)
                ctx_cols = {k: np.stack([a[2][k] for a in admissions], axis=-1)
                            for k in ctx}
                v_new, ctx = _admit_columns(
                    v_new, ctx, jnp.asarray(slot_idx), jnp.asarray(v_cols),
                    {k: jnp.asarray(x) for k, x in ctx_cols.items()})
            v = v_new
