"""Query model + batching policy for the PMV serving subsystem.

Queries are grouped by *family key* — the (algorithm kind, algorithm
parameters) tuple that determines the GIM-V semiring, the edge weights and
therefore the jitted step they can share.  Within a family, waiting queries
are packed into fixed Q-bucket batches (jit specializes per bucket size, so a
small set of buckets keeps the compile cache tiny), and the server admits
waiting queries into retired columns mid-loop (continuous batching,
server.py).

Fairness across families is arrival-order: ``next_batch`` always serves the
family whose *oldest* waiting query arrived first.
"""
from __future__ import annotations

import dataclasses
from collections import deque

__all__ = ["Query", "QueryResult", "QueryBatcher", "DEFAULT_BUCKETS",
           "RETIREMENT_REASONS"]

DEFAULT_BUCKETS = (8, 16, 32, 64)

# Why a query's column left the batch (QueryResult.reason / the server's
# stats()["retirement_reasons"] ledger, ISSUE 7 serving degradation):
#   completed          converged or hit its iteration cap — vector is valid
#   deadline_exceeded  per-query deadline fired mid-solve — vector is the
#                      PARTIAL iterate at retirement (converged=False)
#   shed               admission control refused it (queue over max_queue) —
#                      never iterated, vector is None
#   failed             the batch died on an I/O / integrity error after
#                      retries — vector is None, error says why
RETIREMENT_REASONS = ("completed", "deadline_exceeded", "shed", "failed")

_KINDS = ("pagerank", "rwr", "sssp", "cc")


@dataclasses.dataclass
class Query:
    """One GIM-V query against the resident pre-partitioned matrix.

    spec_kind: 'pagerank' | 'rwr' | 'sssp' | 'cc'.
    source: personalization / source vertex (ignored by pagerank and cc).
    tol: per-query convergence tolerance (the engine's delta metric, applied
      to this query's column only).
    c: restart probability (rwr) / damping (pagerank); part of the family
      key because it is baked into the spec's assign closure.
    max_iters: per-query iteration cap (None -> server default).
    """

    spec_kind: str
    source: int = 0
    tol: float = 1e-6
    c: float = 0.85
    max_iters: int | None = None
    # wall-clock budget from submit(); None = no deadline.  An expired query
    # retires with reason='deadline_exceeded' and its partial iterate.
    deadline_s: float | None = None

    # filled in by the server at submit() time
    qid: int | None = None
    t_submit: float | None = None

    def __post_init__(self):
        if self.spec_kind not in _KINDS:
            raise ValueError(f"unknown spec_kind {self.spec_kind!r}; one of {_KINDS}")

    @property
    def family_key(self) -> tuple:
        if self.spec_kind in ("rwr", "pagerank"):
            return (self.spec_kind, round(float(self.c), 9))
        return (self.spec_kind,)


@dataclasses.dataclass
class QueryResult:
    """Answer to one query: the converged (or capped) per-query vector."""

    qid: int
    query: Query
    vector: object            # np.ndarray [n]; None when shed / failed
    iterations: int
    converged: bool
    latency_s: float          # submit -> retire wall clock
    reason: str = "completed"  # one of RETIREMENT_REASONS
    error: str | None = None   # diagnosis when reason == 'failed'


class QueryBatcher:
    """FIFO queues per family + fixed Q-bucket padding policy."""

    def __init__(self, buckets: tuple[int, ...] = DEFAULT_BUCKETS):
        assert buckets and all(q > 0 for q in buckets)
        self.buckets = tuple(sorted(set(int(q) for q in buckets)))
        self._queues: dict[tuple, deque[tuple[int, Query]]] = {}  # (arrival seq, query)
        self._seq = 0

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def add(self, query: Query) -> None:
        self._queues.setdefault(query.family_key, deque()).append((self._seq, query))
        self._seq += 1

    def bucket_for(self, n_queries: int) -> int:
        """Smallest configured bucket >= n_queries (max bucket if none fit)."""
        for q in self.buckets:
            if n_queries <= q:
                return q
        return self.buckets[-1]

    def next_batch(self) -> tuple[tuple, list[Query]] | None:
        """Pop up to max-bucket queries of the family with the oldest head."""
        live = [(q[0][0], key) for key, q in self._queues.items() if q]
        if not live:
            return None
        _, key = min(live)
        queue = self._queues[key]
        batch = [queue.popleft()[1] for _ in range(min(len(queue), self.buckets[-1]))]
        return key, batch

    def pop_waiting(self, family_key: tuple) -> Query | None:
        """Next waiting query of the family (for mid-loop admission)."""
        queue = self._queues.get(family_key)
        if queue:
            return queue.popleft()[1]
        return None
