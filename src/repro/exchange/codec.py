"""Delta/bit-width id codec for the packed (partition-centric) exchange.

The pre-partitioned block structure is static across iterations, so the
destination-row index set of every (source block, destination block) pair can
be stored ONCE and only value payloads shipped each round (PCPM,
"Accelerating PageRank using Partition-Centric Processing").  Two encodings of
the same sets live here:

1. **Wire/manifest form** (``pack_ids``/``unpack_ids``): sorted ids become
   first-id + successive deltas, packed at the per-pair minimal bit width
   (deltas of a dense set are mostly 1s and compress hard).  This is what the
   store persists as shards and what the id-byte accounting charges.
2. **Device form** (``pack_uniform``/``unpack_uniform``): absolute ids at a
   uniform width from {4, 8, 16, 32} bits (32/width ids per uint32 word), so
   the Pallas unpack-scatter kernel decodes a slot with pure shift/mask vector
   ops — no gather, no cross-tile prefix sums.  Slightly less dense than the
   wire form; that gap is the price of an in-kernel decode.

Everything here is host-side numpy and vectorized (no per-id Python loops).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "PackedIds",
    "HEADER_BYTES",
    "pack_ids",
    "unpack_ids",
    "packed_nbytes",
    "DEVICE_WIDTHS",
    "device_width",
    "pack_uniform",
    "unpack_uniform",
]

# Per-pair stream header on the wire: int32 count + int32 bit width.
HEADER_BYTES = 8

# Uniform widths the device form may use: divisors of 32 so every uint32 word
# holds a whole number of ids and a slot tile maps to a contiguous word tile.
DEVICE_WIDTHS = (4, 8, 16, 32)

_U32 = np.uint64(0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class PackedIds:
    """One (src block, dst block) pair's id set in wire form."""

    words: np.ndarray  # uint32, LSB-first packed delta fields
    count: int         # number of ids
    width: int         # bits per delta field (0 for the empty set)
    n_local: int       # id domain [0, n_local)


def pack_ids(ids, n_local: int) -> PackedIds:
    """Pack a strictly-increasing id set from [0, n_local) into delta fields.

    Fields are [ids[0], ids[1]-ids[0], ...]; the width is the minimal bit
    count for the largest field (>= 1 so the all-{0,1}-delta case still
    round-trips).
    """
    ids = np.asarray(ids, dtype=np.int64)
    if ids.ndim != 1:
        raise ValueError(f"ids must be 1-D, got shape {ids.shape}")
    count = int(ids.size)
    if count == 0:
        return PackedIds(np.zeros(0, np.uint32), 0, 0, int(n_local))
    if ids[0] < 0 or ids[-1] >= n_local:
        raise ValueError(f"ids out of [0, {n_local}): [{ids[0]}, {ids[-1]}]")
    fields = np.diff(ids, prepend=np.int64(0))
    if count > 1 and fields[1:].min() <= 0:
        raise ValueError("ids must be strictly increasing")
    width = max(1, int(fields.max()).bit_length())
    n_words = -(-count * width // 32)
    # One guard word absorbs the high-part write of the last field.
    words = np.zeros(n_words + 1, np.uint64)
    off = np.arange(count, dtype=np.int64) * width
    wi = off // 32
    sh = (off % 32).astype(np.uint64)
    f = fields.astype(np.uint64)
    np.bitwise_or.at(words, wi, (f << sh) & _U32)
    np.bitwise_or.at(words, wi + 1, f >> (np.uint64(32) - sh))
    return PackedIds(words[:n_words].astype(np.uint32), count, width, int(n_local))


def unpack_ids(packed: PackedIds) -> np.ndarray:
    """Inverse of :func:`pack_ids`; returns int64 ids, sorted ascending."""
    return unpack_fields(packed.words, packed.count, packed.width)


def unpack_fields(words: np.ndarray, count: int, width: int) -> np.ndarray:
    """Decode ``count`` delta fields of ``width`` bits and cumsum back to ids."""
    if count == 0:
        return np.zeros(0, np.int64)
    w = np.concatenate([np.asarray(words, np.uint64), np.zeros(1, np.uint64)])
    off = np.arange(count, dtype=np.int64) * width
    wi = off // 32
    sh = (off % 32).astype(np.uint64)
    lo = w[wi] >> sh
    hi = w[wi + 1] << (np.uint64(32) - sh)
    mask = np.uint64((1 << width) - 1)
    fields = ((lo | hi) & mask).astype(np.int64)
    return np.cumsum(fields)


def packed_nbytes(packed: PackedIds) -> int:
    """Wire bytes this set costs once per solve (header + packed words)."""
    return HEADER_BYTES + 4 * int(packed.words.size)


def device_width(n_local: int) -> int:
    """Smallest uniform width that can hold every id AND the pad sentinel
    ``n_local`` (the receive scatter's drop slot)."""
    need = max(1, int(n_local).bit_length())
    for w in DEVICE_WIDTHS:
        if w >= need:
            return w
    raise ValueError(f"n_local={n_local} does not fit a 32-bit id")


def pack_uniform(ids: np.ndarray, width: int) -> np.ndarray:
    """Pack absolute ids [..., p] at a uniform ``width`` into uint32 words
    [..., p*width/32].  ``p`` must be a multiple of 32/width (pad with the
    sentinel first) so sets stay word-aligned."""
    if width not in DEVICE_WIDTHS:
        raise ValueError(f"width {width} not in {DEVICE_WIDTHS}")
    ids = np.asarray(ids)
    k = 32 // width
    p = ids.shape[-1]
    if p % k:
        raise ValueError(f"trailing dim {p} not a multiple of {k} ids/word")
    a = ids.astype(np.uint64).reshape(ids.shape[:-1] + (p // k, k))
    if a.size and int(a.max()) >= (1 << width):
        raise ValueError(f"id {int(a.max())} overflows width {width}")
    sh = np.arange(k, dtype=np.uint64) * np.uint64(width)
    return np.bitwise_or.reduce(a << sh, axis=-1).astype(np.uint32)


def unpack_uniform(words: np.ndarray, width: int, p: int) -> np.ndarray:
    """Inverse of :func:`pack_uniform`: uint32 words [..., W] -> int32 ids
    [..., p] (p <= W * 32/width)."""
    k = 32 // width
    w = np.asarray(words, np.uint64)
    sh = np.arange(k, dtype=np.uint64) * np.uint64(width)
    mask = np.uint64((1 << width) - 1)
    out = (w[..., None] >> sh) & mask
    return out.reshape(w.shape[:-1] + (w.shape[-1] * k,))[..., :p].astype(np.int32)
