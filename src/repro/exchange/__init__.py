"""repro.exchange — partition-centric packed exchange (ROADMAP item 2).

Static per-(source, destination)-block index sets computed once at prepare()
time, delta/bit-width packed (codec), summarized into a hashable
:class:`ExchangePlan` (plan), with the per-iteration send/receive/delta
primitives in runtime.  ``exchange='packed'`` on the engine/server selects
this path; ``exchange='auto'`` gates it on
``cost_model.prefer_packed_exchange``.
"""
from repro.exchange.codec import (
    DEVICE_WIDTHS,
    HEADER_BYTES,
    PackedIds,
    device_width,
    pack_ids,
    pack_uniform,
    packed_nbytes,
    unpack_ids,
    unpack_uniform,
)
from repro.exchange.plan import (
    ExchangePlan,
    build_exchange,
    format_exchange,
    row_sets_from_stripes,
    summarize_row_sizes,
)
from repro.exchange.runtime import (
    delta_update,
    gather_payload,
    pair_slot_mask,
    payload_logical,
    scatter_payload,
)

__all__ = [
    "PackedIds", "HEADER_BYTES", "DEVICE_WIDTHS",
    "pack_ids", "unpack_ids", "packed_nbytes",
    "device_width", "pack_uniform", "unpack_uniform",
    "ExchangePlan", "build_exchange", "format_exchange",
    "row_sets_from_stripes", "summarize_row_sizes",
    "gather_payload", "scatter_payload", "payload_logical",
    "delta_update", "pair_slot_mask",
]
