"""Static exchange plan: per-(src, dst) block index sets + byte accounting.

``build_exchange`` runs once at ``prepare()`` time.  It derives, for every
(destination block i, source block j) pair, the deduplicated sorted set of
destination-local rows block M^(i,j) can touch — static across iterations
because the matrix structure never changes — and materializes:

- ``send_rows`` [b_src, b_dst, p_dev] int32: worker j's gather order for the
  payload it ships to each destination (pad slots carry the sentinel
  ``n_local``).
- ``recv_rows`` = swapaxes(send_rows, 0, 1): worker i's scatter targets for
  each arriving payload (sentinel rows land in the per-set drop slot).
- ``recv_words`` (scatter='kernel' only) [b_dst, W] uint32: the same recv
  sets bit-packed at a uniform width so the Pallas unpack-scatter kernel
  decodes them in VMEM instead of reading int32 rows.

The :class:`ExchangePlan` summary is a frozen (hashable) dataclass of the
static byte model — it rides inside ``StepConfig`` so jitted steps can bake
the constants into their stats, and ``explain()`` renders it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.exchange import codec

__all__ = [
    "ExchangePlan",
    "row_sets_from_stripes",
    "row_sets_from_nnz_template",
    "build_exchange",
    "summarize_row_sizes",
    "format_exchange",
]


@dataclasses.dataclass(frozen=True)
class ExchangePlan:
    """Static summary of one packed-exchange layout (hashable)."""

    b: int
    n_local: int
    p_cap: int          # max index-set size over all (i, j) pairs
    p_dev: int          # word-aligned device slot capacity (>= p_cap)
    width_dev: int      # uniform device bit width (4/8/16/32)
    payload_slots: int  # sum of off-diagonal index-set sizes = values/iter
    id_bytes: int       # one-time wire bytes for all off-diagonal id sets
    bitmap_bytes: int   # per-iteration delta send-mask bytes (off-diagonal)
    pair_rows: tuple    # b*b row-major (dst i, src j) index-set sizes
    pair_widths: tuple  # b*b row-major wire-codec bit widths

    def rows_of(self, i: int, j: int) -> int:
        return self.pair_rows[i * self.b + j]

    def width_of(self, i: int, j: int) -> int:
        return self.pair_widths[i * self.b + j]

    def payload_bytes_per_iter(self, nq: int | None, itemsize: int) -> float:
        """Full-stream (non-delta) payload bytes per iteration."""
        return float(self.payload_slots * (nq or 1) * itemsize)


def row_sets_from_stripes(stripes: list, b: int) -> list:
    """Per-pair sorted unique destination rows from vertical stripes.

    ``stripes[j]`` is source worker j's BlockEdges (seg_local [b, e_cap],
    count [b]); returns ``rows[i][j]`` int64 arrays.
    """
    rows = [[None] * b for _ in range(b)]
    for j, stripe in enumerate(stripes):
        seg = np.asarray(stripe.seg_local)
        cnt = np.asarray(stripe.count)
        for i in range(b):
            c = int(cnt[i])
            rows[i][j] = (np.unique(seg[i, :c]).astype(np.int64) if c
                          else np.zeros(0, np.int64))
    return rows


def row_sets_from_nnz_template(partial_nnz: np.ndarray) -> list:
    """Index-set SIZES only (no ids) — enough for the byte model when the
    stripes are not resident (explain() on a sparse-mode prepare)."""
    b = partial_nnz.shape[0]
    return [[int(partial_nnz[i, j]) for j in range(b)] for i in range(b)]


def build_exchange(
    row_sets: list,
    n_local: int,
    *,
    scatter: str = "segment",
) -> tuple[ExchangePlan, dict]:
    """Build the device arrays + static plan from per-pair row sets.

    Returns ``(plan, arrays)`` where arrays holds numpy tensors (the engine
    device_puts them into the matrix pytree):
      send_rows [b, b, p_dev] int32, indexed [src worker j, dst block i, slot]
      recv_rows [b, b, p_dev] int32, indexed [dst worker i, src block j, slot]
      recv_words [b, W] uint32 (only when scatter='kernel')
    """
    b = len(row_sets)
    pair_rows = np.zeros((b, b), np.int64)
    pair_widths = np.zeros((b, b), np.int64)
    id_bytes = 0
    bitmap_bytes = 0
    for i in range(b):
        for j in range(b):
            ids = row_sets[i][j]
            packed = codec.pack_ids(ids, n_local)
            pair_rows[i, j] = packed.count
            pair_widths[i, j] = packed.width
            if i != j:
                id_bytes += codec.packed_nbytes(packed)
                bitmap_bytes += -(-packed.count // 8)
    p_cap = max(int(pair_rows.max()), 1)
    width_dev = codec.device_width(n_local)
    ids_per_word = 32 // width_dev
    p_dev = -(-p_cap // ids_per_word) * ids_per_word

    send_rows = np.full((b, b, p_dev), n_local, np.int32)
    for i in range(b):
        for j in range(b):
            ids = row_sets[i][j]
            send_rows[j, i, : len(ids)] = ids
    recv_rows = np.ascontiguousarray(send_rows.swapaxes(0, 1))

    arrays = {"send_rows": send_rows, "recv_rows": recv_rows}
    if scatter == "kernel":
        # Per receiving worker: its b sets' words concatenated in set order.
        arrays["recv_words"] = codec.pack_uniform(
            recv_rows, width_dev).reshape(b, -1)

    off = ~np.eye(b, dtype=bool)
    plan = ExchangePlan(
        b=b,
        n_local=int(n_local),
        p_cap=p_cap,
        p_dev=int(p_dev),
        width_dev=width_dev,
        payload_slots=int(pair_rows[off].sum()),
        id_bytes=int(id_bytes),
        bitmap_bytes=int(bitmap_bytes),
        pair_rows=tuple(int(x) for x in pair_rows.reshape(-1)),
        pair_widths=tuple(int(x) for x in pair_widths.reshape(-1)),
    )
    return plan, arrays


def summarize_row_sizes(row_sets: list, n_local: int) -> ExchangePlan:
    """ExchangePlan byte model from index-set SIZES alone (``row_sets[i][j]``
    ints).  Wire widths are upper-bounded by the uniform-spacing delta width,
    so id_bytes is an estimate — used only for explain() previews when the
    packed arrays were not built."""
    b = len(row_sets)
    pair_rows = np.zeros((b, b), np.int64)
    pair_widths = np.zeros((b, b), np.int64)
    id_bytes = 0
    bitmap_bytes = 0
    for i in range(b):
        for j in range(b):
            c = int(row_sets[i][j])
            pair_rows[i, j] = c
            if c:
                # worst-case delta for c sorted ids in [0, n_local)
                gap = max(1, n_local - c + 1)
                pair_widths[i, j] = max(1, int(gap).bit_length())
            if i != j:
                nwords = -(-c * int(pair_widths[i, j]) // 32)
                id_bytes += codec.HEADER_BYTES + 4 * nwords
                bitmap_bytes += -(-c // 8)
    p_cap = max(int(pair_rows.max()), 1)
    width_dev = codec.device_width(n_local)
    ids_per_word = 32 // width_dev
    off = ~np.eye(b, dtype=bool)
    return ExchangePlan(
        b=b, n_local=int(n_local), p_cap=p_cap,
        p_dev=-(-p_cap // ids_per_word) * ids_per_word,
        width_dev=width_dev,
        payload_slots=int(pair_rows[off].sum()),
        id_bytes=int(id_bytes),
        bitmap_bytes=int(bitmap_bytes),
        pair_rows=tuple(int(x) for x in pair_rows.reshape(-1)),
        pair_widths=tuple(int(x) for x in pair_widths.reshape(-1)),
    )


def format_exchange(
    xplan: ExchangePlan,
    *,
    mode: str,
    decision: str,
    capacity: int,
    itemsize: int,
    nq: int | None = None,
    delta_eps: float | None = None,
    estimated: bool = False,
) -> str:
    """Human-readable exchange section for ``explain()``."""
    from repro.core import cost_model  # local import: core imports us too

    b = xplan.b
    padded = cost_model.padded_exchange_bytes(b, capacity, nq, itemsize)
    packed = xplan.payload_bytes_per_iter(nq, itemsize)
    amort = xplan.id_bytes / cost_model.PACKED_ID_AMORTIZATION_ITERS
    rows = np.asarray(xplan.pair_rows).reshape(b, b)
    widths = np.asarray(xplan.pair_widths).reshape(b, b)
    off = ~np.eye(b, dtype=bool)
    lines = [
        "exchange:",
        f"  mode                 {mode} ({decision})",
        f"  index sets           {b}x{b} pairs, p_cap={xplan.p_cap} "
        f"p_dev={xplan.p_dev} dev_width={xplan.width_dev}b"
        + (" [estimated]" if estimated else ""),
        f"  id bytes (once)      {xplan.id_bytes:,} "
        f"(~{amort:,.0f}/iter over {cost_model.PACKED_ID_AMORTIZATION_ITERS:.0f} iters)",
        f"  payload bytes/iter   packed {packed:,.0f} vs padded {padded:,.0f}",
    ]
    if delta_eps is not None:
        lines.append(
            f"  delta iteration      eps={delta_eps:g} "
            f"(+{xplan.bitmap_bytes:,} bitmap bytes/iter, payload decays)")
    if off.any():
        r = rows[off]
        w = widths[off]
        lines.append(
            f"  off-diag set sizes   min={int(r.min())} "
            f"med={int(np.median(r))} max={int(r.max())}  "
            f"wire widths {int(w.min())}-{int(w.max())}b")
    if b <= 8:
        lines.append("  per-pair rows (dst i x src j):")
        for i in range(b):
            cells = " ".join(f"{int(rows[i, j]):>7d}" for j in range(b))
            lines.append(f"    i={i}  {cells}")
    return "\n".join(lines)
