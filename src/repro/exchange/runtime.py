"""Device-side primitives of the packed exchange (send gather, receive
scatter, delta suppression).

The sender gathers its partials at the static per-pair row order
(``send_rows``) — no per-iteration compaction, no overflow (the index sets
ARE the structural support).  The receiver scatters the arriving payload at
the mirrored ``recv_rows`` (or decodes the bit-packed ``recv_words`` inside
the Pallas kernel).  Delta iteration keeps the previously-sent payload as
carried state and re-sends only rows whose value moved beyond ε; for ε=0 the
"stale" rows are bitwise the current ones, so the receive is exact.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
from jax import lax

from repro.core.gimv import GimvSpec
from repro.core.sparse_exchange import count_non_identity, scatter_partials

__all__ = ["gather_payload", "scatter_payload", "delta_update", "pair_slot_mask"]


def _reduce_sum(x, axis_name):
    return lax.psum(x, axis_name) if axis_name is not None else x


def gather_payload(spec: GimvSpec, partials: jnp.ndarray,
                   send_rows: jnp.ndarray) -> jnp.ndarray:
    """Gather partials [..., b, n_local(, Q)] at send_rows [..., b, p] ->
    payload [..., b, p(, Q)].  Sentinel slots (row == n_local) yield the
    combineAll identity, so the receive's drop slot sees exact no-ops."""
    n_local = partials.shape[-2] if partials.ndim == send_rows.ndim + 1 \
        else partials.shape[-1]
    ident = jnp.asarray(spec.identity, partials.dtype)
    pad = send_rows >= n_local
    safe = jnp.where(pad, 0, send_rows)
    if partials.ndim == send_rows.ndim + 1:  # trailing query axis
        val = jnp.take_along_axis(partials, safe[..., None], axis=-2)
        return jnp.where(pad[..., None], ident, val)
    val = jnp.take_along_axis(partials, safe, axis=-1)
    return jnp.where(pad, ident, val)


def payload_logical(spec: GimvSpec, payload: jnp.ndarray, axis_name) -> jnp.ndarray:
    """Value-level non-identity count of a payload — identical to the sparse
    path's ``logical_elems`` because the structural row sets cover exactly the
    slots a value-compacted exchange could ship."""
    return _reduce_sum(count_non_identity(spec, payload), axis_name)


def scatter_payload(
    spec: GimvSpec,
    val: jnp.ndarray,
    n_local: int,
    *,
    recv_rows: jnp.ndarray | None = None,
    recv_words: jnp.ndarray | None = None,
    p_dev: int = 0,
    width: int = 0,
    method: str = "segment",
    interpret: bool = False,
) -> jnp.ndarray:
    """combineAll of received payloads val [..., b, p(, Q)] -> r [..., n_local(, Q)].

    method='segment' scatters via the int32 ``recv_rows`` (sentinel rows land
    in the per-worker drop slot, exactly like ``scatter_partials``).
    method='kernel' with ``recv_words`` decodes the uniform-width bit-packed
    ids inside the Pallas indexed-payload kernel — the ids never exist as
    int32 on device.
    """
    if method == "kernel" and recv_words is not None:
        from repro.kernels.block_gimv import semiring_of
        from repro.kernels.scatter_combine import (
            packed_scatter_combine_gimv, packed_scatter_combine_gimv_multi)

        batched = (val.ndim - recv_words.ndim) == 2
        q = val.shape[-1] if batched else None
        lead = val.shape[:-3] if batched else val.shape[:-2]
        b = val.shape[-3] if batched else val.shape[-2]
        n_sets = math.prod(lead) if lead else 1
        seg_w = n_local + 1
        set_slots = b * p_dev  # slots sharing one worker's output segment
        flat_val = val.reshape((n_sets * set_slots, q) if batched else (-1,))
        semiring = semiring_of(spec.combine2, spec.combine_all)
        fn = packed_scatter_combine_gimv_multi if batched else packed_scatter_combine_gimv
        out = fn(recv_words.reshape(-1), flat_val, n_sets * seg_w,
                 set_slots=set_slots, n_local=n_local, width=width,
                 semiring=semiring, interpret=interpret)
        out = out.reshape(lead + ((seg_w, q) if batched else (seg_w,)))
        return out[..., :n_local, :] if batched else out[..., :n_local]
    return scatter_partials(spec, recv_rows, val, n_local,
                            method=method, interpret=interpret)


def pair_slot_mask(send_rows: jnp.ndarray, n_local: int, axis_name) -> jnp.ndarray:
    """Bool [..., b, p]: slots that count toward wire accounting — valid
    (non-sentinel) rows of OFF-DIAGONAL pairs (the diagonal partial never
    crosses the interconnect; both the padded formula and the packed byte
    model are b(b-1) quantities)."""
    valid = send_rows < n_local
    b = send_rows.shape[-2]
    dst = jnp.arange(b, dtype=jnp.int32)
    if axis_name is not None:
        src = lax.axis_index(axis_name)
        off = dst != src                                   # [b]
    else:
        b_w = send_rows.shape[0]
        off = jnp.arange(b_w, dtype=jnp.int32)[:, None] != dst[None, :]  # [b_w, b]
    return valid & off[..., None]


def delta_update(spec: GimvSpec, payload: jnp.ndarray, prev: jnp.ndarray,
                 eps: float, pair_mask: jnp.ndarray, axis_name):
    """Suppress rows whose payload moved <= eps since the last send.

    Returns (shipped, sent_rows, suppressed_rows).  ``shipped`` carries the
    fresh payload on rows that moved and the previously-sent value elsewhere
    (the receiver-side cache, folded into the stream so the scatter stays
    oblivious).  eps=0 compares with ``!=`` — bitwise exact, and immune to
    the inf - inf = NaN trap of an |diff| test.  A trailing query axis
    re-sends a row when ANY query moved (one shared send mask per row keeps
    the id-free wire order intact).
    """
    batched = payload.ndim == pair_mask.ndim + 1
    if eps == 0.0:
        changed = payload != prev
    else:
        changed = jnp.abs(payload - prev) > eps
    if batched:
        changed = jnp.any(changed, axis=-1)
    shipped = jnp.where(changed[..., None] if batched else changed, payload, prev)
    sent = _reduce_sum(jnp.sum((changed & pair_mask).astype(jnp.float32)), axis_name)
    total = _reduce_sum(jnp.sum(pair_mask.astype(jnp.float32)), axis_name)
    return shipped, sent, total - sent
