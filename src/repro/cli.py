"""``repro`` command-line entry points (``python -m repro ...``).

Currently one command family:

    repro store verify <store-dir>     audit a block store's shards against
                                       the manifest's ingest-time checksums
                                       (exit 0 clean, 1 corrupt/missing,
                                       2 unverifiable)

Kept deliberately tiny and dependency-light: the CLI imports the store
layer lazily so ``repro --help`` never pays the jax import.
"""
from __future__ import annotations

import argparse
import sys

__all__ = ["main"]


def _cmd_store_verify(args) -> int:
    from repro.store.verify import verify_store

    report = verify_store(args.store_dir)
    print(report.summary())
    if report.skipped:
        return 2
    return 0 if report.ok else 1


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(prog="repro")
    sub = parser.add_subparsers(dest="command", required=True)

    store = sub.add_parser("store", help="block-store maintenance")
    store_sub = store.add_subparsers(dest="store_command", required=True)

    verify = store_sub.add_parser(
        "verify", help="audit every shard against the manifest checksums")
    verify.add_argument("store_dir", help="ingested block-store directory")
    verify.set_defaults(fn=_cmd_store_verify)

    return parser


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
