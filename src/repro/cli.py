"""``repro`` command-line entry points (``python -m repro ...``).

Command families:

    repro store verify <store-dir>     audit a block store's shards against
                                       the manifest's ingest-time checksums
                                       (exit 0 clean, 1 corrupt/missing,
                                       2 unverifiable)

    repro obs merge <out> <in...>      merge Chrome trace files (e.g. one
                                       per host) into one multi-lane trace,
                                       schema-validated
    repro obs report <BENCH_obs.json>  per-kind calibration ratios, overhead
                                       gates, and the fleet straggler digest
    repro obs top <url>                `top`-style live frames from a
                                       PMVServer telemetry endpoint

Kept deliberately tiny and dependency-light: the CLI imports the store /
obs layers lazily so ``repro --help`` never pays the jax import.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

__all__ = ["main"]


def _cmd_store_verify(args) -> int:
    from repro.store.verify import verify_store

    report = verify_store(args.store_dir)
    print(report.summary())
    if report.skipped:
        return 2
    return 0 if report.ok else 1


def _cmd_obs_merge(args) -> int:
    from repro.obs.fleet import merge_trace_docs
    from repro.obs.trace import validate_chrome_trace

    docs = []
    for path in args.traces:
        with open(path) as f:
            docs.append(json.load(f))
    merged = merge_trace_docs(docs, labels=args.labels)
    validate_chrome_trace(merged)
    with open(args.out, "w") as f:
        json.dump(merged, f)
    events = [e for e in merged["traceEvents"] if e.get("ph") != "M"]
    lanes = {(e["pid"], e["tid"]) for e in events}
    print(f"merged {len(args.traces)} trace(s) -> {args.out}: "
          f"{len(events)} events across {len(lanes)} lanes")
    return 0


def _cmd_obs_report(args) -> int:
    from repro.obs.report import format_calibration

    with open(args.bench) as f:
        doc = json.load(f)
    print(format_calibration(doc))
    return 0


def _cmd_obs_top(args) -> int:
    from urllib.request import urlopen

    from repro.obs.live import format_top

    url = args.url.rstrip("/") + "/metrics.json"
    for i in range(args.count):
        if i:
            time.sleep(args.interval)
        with urlopen(url) as resp:
            snapshot = json.load(resp)
        print(format_top(snapshot))
    return 0


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(prog="repro")
    sub = parser.add_subparsers(dest="command", required=True)

    store = sub.add_parser("store", help="block-store maintenance")
    store_sub = store.add_subparsers(dest="store_command", required=True)

    verify = store_sub.add_parser(
        "verify", help="audit every shard against the manifest checksums")
    verify.add_argument("store_dir", help="ingested block-store directory")
    verify.set_defaults(fn=_cmd_store_verify)

    obs = sub.add_parser("obs", help="observability: traces, reports, live")
    obs_sub = obs.add_subparsers(dest="obs_command", required=True)

    merge = obs_sub.add_parser(
        "merge", help="merge Chrome trace files into one multi-lane trace")
    merge.add_argument("out", help="merged trace output path")
    merge.add_argument("traces", nargs="+", help="input trace.json files")
    merge.add_argument("--labels", nargs="*", default=None,
                       help="one lane-prefix label per input (default: "
                            "trace0, trace1, ...)")
    merge.set_defaults(fn=_cmd_obs_merge)

    report = obs_sub.add_parser(
        "report", help="print the calibration/fleet digest of a BENCH_obs.json")
    report.add_argument("bench", help="BENCH_obs.json path")
    report.set_defaults(fn=_cmd_obs_report)

    top = obs_sub.add_parser(
        "top", help="live text dashboard from a telemetry endpoint")
    top.add_argument("url", help="base URL of PMVServer telemetry "
                                 "(server.telemetry.url)")
    top.add_argument("--count", type=int, default=1,
                     help="frames to print (default 1)")
    top.add_argument("--interval", type=float, default=2.0,
                     help="seconds between frames (default 2)")
    top.set_defaults(fn=_cmd_obs_top)

    return parser


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
