"""AdamW with decoupled weight decay, global-norm clipping, and a
warmup+cosine schedule.  Moments in f32; supports bf16 params (master copy
semantics: updates computed in f32 then cast back).

No optax dependency — the optimizer is part of the substrate per the
assignment ("build every substrate the paper depends on").
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        vhat = nu / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"grad_norm": gnorm, "lr": lr}
