"""GPipe-style pipeline parallelism over the 'pod' axis (DESIGN.md §6).

The multi-pod mesh's pod axis defaults to cross-pod DP; this module provides
the alternative: each pod holds a contiguous stage of layers and
microbatches flow through a `ppermute` ring — inter-pod traffic becomes one
activation tensor per microbatch-step instead of gradient all-reduces, the
right trade when layers/pod are deep and the DCI is thin.

`pipeline_apply` is the schedule core (fwd-only shown; autodiff through it
gives the standard GPipe backward with bubble 2(S-1)/(M+S-1)).  It is a
shard_map manual over the pipeline axis with data/model axes left auto, so
each stage's interior still uses the full TP/FSDP sharding.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_fn, stage_params, microbatches, mesh, *, axis: str = "pod"):
    """Run `n_stages` sequential stages over M microbatches on a ring.

    stage_fn: (params_one_stage, x) -> y (same shape as x).
    stage_params: pytree stacked on a leading [n_stages] axis (sharded over
        `axis` by shard_map).
    microbatches: [M, ...] (replicated across the pipeline axis; the batch
        interior may still be sharded over data axes).
    Returns [M, ...] outputs of the final stage.
    """
    n_stages = mesh.shape[axis]
    M = microbatches.shape[0]
    steps = M + n_stages - 1

    def body(params_local, micro):
        # params_local: [1, ...] slice of the stacked stage params
        p = jax.tree.map(lambda a: a[0], params_local)
        stage = lax.axis_index(axis)
        zero = jnp.zeros_like(micro[0])

        def step(carry, t):
            inflight, outputs = carry
            # stage 0 injects microbatch t (while available); others use the
            # activation received from the previous stage last step.
            inject = lax.dynamic_index_in_dim(micro, jnp.minimum(t, M - 1), 0,
                                              keepdims=False)
            x = jnp.where(stage == 0, inject, inflight)
            y = stage_fn(p, x)
            # last stage records its result for microbatch t - (S-1)
            out_slot = t - (n_stages - 1)
            outputs = lax.cond(
                (stage == n_stages - 1) & (out_slot >= 0),
                lambda o: lax.dynamic_update_index_in_dim(o, y, jnp.maximum(out_slot, 0), 0),
                lambda o: o,
                outputs)
            # ring-shift activations to the next stage
            nxt = lax.ppermute(y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outputs), None

        init = (zero, jnp.zeros_like(micro))
        (_, outputs), _ = lax.scan(step, init, jnp.arange(steps))
        # only the last stage holds results (zeros elsewhere): psum
        # broadcasts them so the output is replicated over the pipeline axis.
        return lax.psum(outputs, axis)

    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        axis_names={axis},
        check_vma=False,
    )(stage_params, microbatches)
