"""Sharded checkpointing with atomic commit + elastic re-shard on restore.

Layout:
    <dir>/step_000123.tmp/   (written)   -> os.replace -> <dir>/step_000123/
        manifest.json        (treedef, shapes, dtypes, mesh shape at save)
        arrays.npz           (flat arrays keyed by path)

- Atomic commit: a checkpoint directory either fully exists or not at all
  (rename is atomic); partial writes are left as .tmp and ignored/GC'd.
- Elastic restore: arrays are stored unsharded (host-gathered); ``restore``
  device_puts them under *any* target mesh/sharding — scaling the mesh up,
  down, or routing around a dead pod is a restore-time decision.
  (At 1000+ node scale the same manifest protocol holds per-host shard files;
  the gather/scatter here is the single-host degenerate case.)
- Retention: keep the last `keep` checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "all_steps"]

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf) for path, leaf in flat}


def save(ckpt_dir: str, step: int, state, *, keep: int = 3) -> str:
    """state: arbitrary pytree (params, opt_state, counters...)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    arrays = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **{k: v for k, v in arrays.items()})
    treedef = jax.tree_util.tree_structure(state)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": list(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit

    for old in all_steps(ckpt_dir)[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{old:09d}"), ignore_errors=True)
    return final


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        m = _STEP_RE.match(d)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of
    NamedShardings for the *target* mesh (elastic re-shard)."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for keypath, leaf in flat_like:
        k = jax.tree_util.keystr(keypath)
        arr = arrays[k]
        assert tuple(arr.shape) == tuple(leaf.shape), (k, arr.shape, leaf.shape)
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), out)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree
