from repro.training.optimizer import adamw_init, adamw_update, OptConfig
from repro.training.train_step import make_train_step, TrainConfig
from repro.training.data import SyntheticTokenPipeline
from repro.training import checkpoint

__all__ = [
    "adamw_init",
    "adamw_update",
    "OptConfig",
    "make_train_step",
    "TrainConfig",
    "SyntheticTokenPipeline",
    "checkpoint",
]
