"""Deterministic, stateless data pipeline.

``batch_at(step)`` is a pure function of (seed, step) via counter-based RNG
(Philox), so checkpoint/restart and elastic re-sharding recover the *exact*
token stream with no pipeline state beyond the step counter — the data-side
half of the fault-tolerance contract (DESIGN.md §6).

Real deployments swap `_materialize` for a deterministic tokenized-shard
reader keyed the same way ((seed, step, host_slice) -> examples).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticTokenPipeline"]


@dataclasses.dataclass(frozen=True)
class SyntheticTokenPipeline:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    # optional stub-modality inputs
    vis_tokens: int = 0
    enc_len: int = 0
    d_model: int = 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(key=self.seed, counter=step))

    def batch_at(self, step: int, *, host_slice: slice | None = None) -> dict:
        """Global (or host-sliced) batch for `step`; identical across restarts."""
        rng = self._rng(step)
        tokens = rng.integers(0, self.vocab, size=(self.global_batch, self.seq_len), dtype=np.int32)
        batch = {"tokens": tokens}
        if self.vis_tokens:
            batch["vis_emb"] = rng.normal(0, 0.1, size=(self.global_batch, self.vis_tokens, self.d_model)).astype(np.float32)
        if self.enc_len:
            batch["enc_emb"] = rng.normal(0, 0.1, size=(self.global_batch, self.enc_len, self.d_model)).astype(np.float32)
        if host_slice is not None:
            batch = {k: v[host_slice] for k, v in batch.items()}
        return batch
