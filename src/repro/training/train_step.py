"""Train step factory: loss -> grad -> AdamW, with gradient accumulation and
optional int8 error-feedback gradient compression on the cross-pod axis.

Gradient accumulation (microbatch scan) is both a memory knob (activation
live-set divides by `grad_accum`) and the compute/communication overlap
surface: XLA schedules microbatch i+1's forward against microbatch i's grad
reductions.

Cross-pod compression (`compress_pod`): the pod axis crosses the slower
inter-pod links, so its all-reduce is the one worth compressing.  We run the
whole step inside shard_map manual over 'pod' (auto over data/model),
quantize each gradient tensor to int8 with a psum-shared per-tensor scale,
all-reduce the int8 payload (4x fewer wire bytes than f32), and keep the
quantization residual in an error-feedback buffer so compression noise does
not bias convergence (Seide et al., 1-bit SGD lineage).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.training.optimizer import OptConfig, adamw_init, adamw_update

__all__ = ["TrainConfig", "make_train_step", "init_train_state", "quantize_psum"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    grad_accum: int = 1
    compress_pod: bool = False
    pod_axis: str = "pod"


def init_train_state(model, params, tcfg: TrainConfig):
    state = {"opt": adamw_init(params), "step": jnp.zeros((), jnp.int32)}
    if tcfg.compress_pod:
        state["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def quantize_psum(g, axis_name):
    """int8 error-feedback all-reduce of one tensor; returns (mean_g, residual)."""
    npods = jax.lax.psum(1, axis_name)
    scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    wire = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int payload on the wire
    mean_g = wire.astype(jnp.float32) * scale / npods
    residual = g - q.astype(jnp.float32) * scale
    return mean_g, residual


def _accum_grads(loss_fn, params, batch, grad_accum: int):
    """Microbatch scan; grads accumulated in f32."""
    if grad_accum == 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def split(x):
        return x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:])

    micro = jax.tree.map(split, batch)

    def body(carry, mb):
        acc, loss_acc = carry
        (loss, _metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
        return (acc, loss_acc + loss), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.zeros(())), micro)
    grads = jax.tree.map(lambda g: g / grad_accum, gsum)
    loss = loss_sum / grad_accum
    return loss, {"ce": loss}, grads


def make_train_step(model, tcfg: TrainConfig, mesh=None):
    """Returns step(params, state, batch) -> (params', state', metrics).

    Plain mode relies on pjit auto-sharding end to end.  compress_pod mode
    wraps the step in shard_map manual over the pod axis (auto elsewhere).
    """
    loss_fn = model.loss_fn

    def plain_step(params, state, batch):
        loss, metrics, grads = _accum_grads(loss_fn, params, batch, tcfg.grad_accum)
        new_params, new_opt, om = adamw_update(tcfg.opt, params, grads, state["opt"])
        new_state = dict(state, opt=new_opt, step=state["step"] + 1)
        return new_params, new_state, {"loss": loss, **metrics, **om}

    if not tcfg.compress_pod:
        return plain_step

    assert mesh is not None and tcfg.pod_axis in mesh.axis_names
    from jax.sharding import PartitionSpec as P

    axis = tcfg.pod_axis

    def pod_step(params, state, batch):
        # local (per-pod) gradients; data/model axes still auto-sharded.
        loss, metrics, grads = _accum_grads(loss_fn, params, batch, tcfg.grad_accum)
        loss = jax.lax.pmean(loss, axis)

        def combine(g, ef):
            mean_g, residual = quantize_psum(g.astype(jnp.float32) + ef, axis)
            return mean_g, residual

        flat_g, tdef = jax.tree.flatten(grads)
        flat_ef = tdef.flatten_up_to(state["ef"])
        pairs = [combine(g, e) for g, e in zip(flat_g, flat_ef)]
        grads = tdef.unflatten([p[0] for p in pairs])
        new_ef = tdef.unflatten([p[1] for p in pairs])

        new_params, new_opt, om = adamw_update(tcfg.opt, params, grads, state["opt"])
        new_state = dict(state, opt=new_opt, ef=new_ef, step=state["step"] + 1)
        return new_params, new_state, {"loss": loss, **metrics, **om}

    # batch sharded over pod; params/state replicated over pod (sharded over
    # data/model, which stay in auto mode: only the pod axis is manual).
    return jax.shard_map(
        pod_step,
        mesh=mesh,
        in_specs=(P(), P(), P(axis)),
        out_specs=(P(), P(), P()),
        axis_names={axis},
        check_vma=False,
    )
